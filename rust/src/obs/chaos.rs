//! Deterministic fault injection for the resilience test harness.
//!
//! Production code is sprinkled with named **fault points** —
//! `chaos::fire("prepare-fail")` in the registry, `"conn-drop"` in the
//! connection loop, `"corrupt-sidecar"` in the `.bcoo` cache read,
//! `"slow-stage"` inside [`crate::obs::span`] — that do nothing unless
//! a fault spec arms them. With nothing armed every hook is a single
//! relaxed atomic load (the same kill-switch shape as the tracing
//! `enabled()` check), so the hooks are free on the hot path; the
//! `micro_obs` bench smoke asserts as much.
//!
//! Faults are armed by the `BOBA_FAULTS` environment variable at
//! server start or programmatically / via `POST /debug/faults` in
//! tests. The spec grammar is a comma-separated list of:
//!
//! ```text
//! prepare-fail[:COUNT[:SKIP]]      fail the next COUNT prepares (after SKIP)
//! conn-drop[:COUNT[:SKIP]]        drop the next COUNT connections pre-read
//! corrupt-sidecar[:COUNT[:SKIP]]  treat the next COUNT sidecar reads as corrupt
//! slow-stage:MS[:COUNT[:SKIP]]    delay the next COUNT stage spans by MS ms
//! wal-io-error[:COUNT[:SKIP]]     fail the next COUNT WAL appends before writing
//! wal-torn-write[:COUNT[:SKIP]]   write a torn (partial) record, then poison the log
//! crash-after-append[:COUNT[:SKIP]] abort() the process after a durable append
//! compact-fail:STAGE[:COUNT[:SKIP]] abort compaction at stage (0=pre-, 1=post-checkpoint)
//! ```
//!
//! `COUNT` defaults to 1; `SKIP` (default 0) skips that many
//! occurrences first, so "fail the third prepare" is
//! `prepare-fail:1:2`. Firing is **counter-based and therefore fully
//! deterministic**: the same spec against the same request sequence
//! injects the same faults, which is what lets the integration tests
//! assert exact outcomes instead of retry-until-flaky.

use crate::util::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// One armed fault point: optional parameter (milliseconds for
/// `slow-stage`), remaining firing budget, occurrences to skip first.
#[derive(Debug, Clone, Copy)]
struct Fault {
    param: u64,
    budget: u64,
    skip: u64,
}

/// Fast-path arm flag: one relaxed load decides "no faults configured"
/// without touching the table lock.
static ARMED: AtomicBool = AtomicBool::new(false);
static TABLE: Mutex<BTreeMap<String, Fault>> = Mutex::new(BTreeMap::new());

/// Serializes every test that mutates the process-global fault table —
/// this module's own unit tests and the router's `/debug/faults` test
/// share it so they cannot clobber each other's armed state.
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Fault-point names that take a leading numeric parameter in the spec.
const PARAM_POINTS: &[&str] = &["slow-stage", "compact-fail", "test-param"];
/// All fault-point names the code base hooks — unknown names in a spec
/// are an error so typos fail loudly instead of silently never firing.
/// `test-point`/`test-param` are hooked by nothing: the unit tests use
/// them to exercise arming/budget/skip mechanics without racing the
/// real hooks that concurrently-running tests drive (the table is
/// process-global).
const KNOWN_POINTS: &[&str] = &[
    "prepare-fail",
    "conn-drop",
    "corrupt-sidecar",
    "slow-stage",
    "wal-io-error",
    "wal-torn-write",
    "crash-after-append",
    "compact-fail",
    "test-point",
    "test-param",
];

/// True when any fault point is armed. One relaxed atomic load — every
/// hook checks this before touching the table.
#[inline]
pub fn enabled() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Fire the fault point `name` if it is armed with remaining budget:
/// returns `Some(param)` (the `MS` field for `slow-stage`, 0 for the
/// others) when the fault should be injected, `None` otherwise.
/// Decrements the budget (or the skip counter) on each armed call.
pub fn fire(name: &str) -> Option<u64> {
    if !enabled() {
        return None;
    }
    let mut table = TABLE.lock().unwrap();
    let fired = match table.get_mut(name) {
        Some(f) if f.skip > 0 => {
            f.skip -= 1;
            None
        }
        Some(f) if f.budget > 0 => {
            f.budget -= 1;
            Some(f.param)
        }
        _ => None,
    };
    if fired.is_some() && table.values().all(|f| f.budget == 0) {
        ARMED.store(false, Ordering::Relaxed);
    }
    fired
}

/// Convenience wrapper: true when [`fire`] fires (for points whose
/// parameter is unused).
pub fn should(name: &str) -> bool {
    fire(name).is_some()
}

/// Replace the armed fault table from a spec string (see the module
/// docs for the grammar). An empty spec clears all faults.
pub fn set_spec(spec: &str) -> anyhow::Result<()> {
    let mut next: BTreeMap<String, Fault> = BTreeMap::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':');
        let name = parts.next().unwrap_or_default();
        if !KNOWN_POINTS.contains(&name) {
            anyhow::bail!("unknown fault point {name:?} (known: {})", KNOWN_POINTS.join(", "));
        }
        let mut nums = Vec::with_capacity(3);
        for p in parts {
            nums.push(
                p.parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("fault {entry:?}: {p:?} is not a number"))?,
            );
        }
        let takes_param = PARAM_POINTS.contains(&name);
        if takes_param && nums.is_empty() {
            anyhow::bail!("fault {name} needs a parameter, e.g. {name}:50");
        }
        if nums.len() > 2 + takes_param as usize {
            anyhow::bail!("fault {entry:?}: too many fields");
        }
        let mut it = nums.into_iter();
        let param = if takes_param { it.next().unwrap() } else { 0 };
        let budget = it.next().unwrap_or(1);
        let skip = it.next().unwrap_or(0);
        next.insert(name.to_string(), Fault { param, budget, skip });
    }
    let armed = next.values().any(|f| f.budget > 0);
    *TABLE.lock().unwrap() = next;
    ARMED.store(armed, Ordering::Relaxed);
    Ok(())
}

/// Disarm every fault point.
pub fn clear() {
    TABLE.lock().unwrap().clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Arm faults from `BOBA_FAULTS` if set. A malformed spec is reported
/// on stderr and ignored (a typo must not take the server down — the
/// debug endpoint reports what is actually armed).
pub fn init_from_env() {
    if let Ok(spec) = std::env::var("BOBA_FAULTS") {
        if let Err(e) = set_spec(&spec) {
            eprintln!("[boba] ignoring BOBA_FAULTS: {e:#}");
        }
    }
}

/// The armed fault table as JSON (served by `GET /debug/faults`):
/// `{"armed":bool,"faults":[{"point","param","remaining","skip"},..]}`.
pub fn snapshot_json() -> Json {
    let table = TABLE.lock().unwrap();
    let faults: Vec<Json> = table
        .iter()
        .map(|(name, f)| {
            Json::obj(vec![
                ("point", Json::Str(name.clone())),
                ("param", Json::Num(f.param as f64)),
                ("remaining", Json::Num(f.budget as f64)),
                ("skip", Json::Num(f.skip as f64)),
            ])
        })
        .collect();
    Json::obj(vec![("armed", Json::Bool(enabled())), ("faults", Json::Arr(faults))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_is_free_and_never_fires() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        clear();
        assert!(!enabled());
        assert!(fire("test-point").is_none());
        assert!(!should("test-point"));
    }

    #[test]
    fn budget_and_skip_are_deterministic() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_spec("test-point:2:1").unwrap();
        assert!(enabled());
        assert!(!should("test-point"), "first occurrence skipped");
        assert!(should("test-point"));
        assert!(should("test-point"));
        assert!(!should("test-point"), "budget exhausted");
        assert!(!enabled(), "exhausting every budget disarms the fast path");
        clear();
    }

    #[test]
    fn param_points_carry_their_parameter() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_spec("test-param:75:1").unwrap();
        assert_eq!(fire("test-param"), Some(75));
        assert_eq!(fire("test-param"), None);
        clear();
    }

    #[test]
    fn spec_errors_and_clearing() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert!(set_spec("no-such-fault:1").is_err());
        assert!(set_spec("slow-stage").is_err(), "slow-stage needs its ms parameter");
        assert!(set_spec("prepare-fail:x").is_err());
        assert!(set_spec("prepare-fail:1:2:3").is_err(), "too many fields");
        set_spec("test-point:3").unwrap();
        set_spec("").unwrap();
        assert!(!enabled());
        let snap = snapshot_json().render();
        assert!(snap.contains("\"armed\":false"), "snapshot was {snap}");
    }

    #[test]
    fn snapshot_lists_armed_points() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_spec("test-point:2,test-param:10:4:1").unwrap();
        let snap = snapshot_json().render();
        assert!(snap.contains("\"point\":\"test-point\""), "snapshot was {snap}");
        assert!(snap.contains("\"remaining\":4"), "snapshot was {snap}");
        clear();
    }
}
