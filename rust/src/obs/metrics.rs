//! Hand-rolled Prometheus text exposition (format version 0.0.4) — the
//! serializer behind `GET /metrics`.
//!
//! No client library resolves offline, and the subset of the format the
//! crate needs is small: `# HELP` / `# TYPE` headers per family,
//! `name{labels} value` samples, and histograms as *cumulative* `le`
//! buckets ending in `+Inf` plus `_sum` / `_count`. The builder owns
//! exactly that subset so the emission rules (escaping, cumulative
//! conversion, seconds units) live in one place and are testable
//! without a server; the conformance suite in `tests/obs_conformance.rs`
//! holds the output to the format contract via [`super::text`], the
//! matching parser.
//!
//! Convention: time histograms are recorded in microseconds
//! ([`Histogram`]) but *exposed* in seconds, per Prometheus base-unit
//! practice — scrapers should never have to guess units from a name.

use super::hist::{Histogram, BUCKETS};

/// Incremental builder for one exposition document.
#[derive(Default)]
pub struct PromText {
    out: String,
}

/// Render a sample value the way Prometheus expects: integers bare,
/// floats in shortest form, infinities as `+Inf`/`-Inf`.
fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() };
    }
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value (`\` → `\\`, `"` → `\"`, newline → `\n`).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

impl PromText {
    /// New empty document.
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Start a metric family: emits the `# HELP` and `# TYPE` lines.
    /// Call once per family, before its samples; `typ` is one of
    /// `counter`, `gauge`, `histogram`.
    pub fn family(&mut self, name: &str, typ: &str, help: &str) {
        // HELP text escapes backslash and newline only (the format
        // leaves quotes alone outside label values).
        let help = help.replace('\\', "\\\\").replace('\n', "\\n");
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(&help);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(typ);
        self.out.push('\n');
    }

    fn sample_name(&mut self, name: &str, labels: &[(&str, &str)]) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
    }

    /// Emit one sample line (`name{labels} value`). Used for counters
    /// and gauges; histograms go through [`Self::histogram_us`] /
    /// [`Self::histogram_buckets`].
    pub fn value(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.sample_name(name, labels);
        self.out.push(' ');
        self.out.push_str(&fmt_value(v));
        self.out.push('\n');
    }

    /// Emit a histogram family's samples from explicit non-cumulative
    /// buckets: `(upper_bound, count)` pairs in ascending bound order.
    /// Converts to cumulative counts, trims trailing empty buckets
    /// (keeping at least one finite bound so the shape is visible), and
    /// always terminates with `+Inf`, `_sum`, `_count`.
    pub fn histogram_buckets(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        buckets: &[(f64, u64)],
        sum: f64,
        count: u64,
    ) {
        let last_used = buckets.iter().rposition(|&(_, c)| c > 0).map_or(0, |i| i + 1);
        let keep = last_used.max(1).min(buckets.len());
        let mut cum = 0u64;
        for &(bound, c) in &buckets[..keep] {
            cum += c;
            let mut with_le: Vec<(&str, &str)> = labels.to_vec();
            let le = fmt_value(bound);
            with_le.push(("le", &le));
            self.value(&format!("{name}_bucket"), &with_le, cum as f64);
        }
        let mut with_le: Vec<(&str, &str)> = labels.to_vec();
        with_le.push(("le", "+Inf"));
        self.value(&format!("{name}_bucket"), &with_le, count as f64);
        self.value(&format!("{name}_sum"), labels, sum);
        self.value(&format!("{name}_count"), labels, count as f64);
    }

    /// Emit a [`Histogram`] (microsecond domain) as a seconds-unit
    /// Prometheus histogram.
    pub fn histogram_us(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let counts = h.bucket_counts();
        let buckets: Vec<(f64, u64)> = (0..BUCKETS)
            .map(|i| (Histogram::bucket_upper_us(i) as f64 / 1e6, counts[i]))
            .collect();
        self.histogram_buckets(name, labels, &buckets, h.sum_us() as f64 / 1e6, h.count());
    }

    /// Finish the document.
    pub fn render(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn families_values_and_escaping() {
        let mut p = PromText::new();
        p.family("boba_requests_total", "counter", "Requests served.");
        p.value("boba_requests_total", &[("endpoint", "spmv")], 42.0);
        p.value("boba_requests_total", &[("endpoint", "a\"b\\c")], 1.0);
        p.family("boba_uptime_seconds", "gauge", "Uptime.");
        p.value("boba_uptime_seconds", &[], 1.5);
        let text = p.render();
        assert!(text.contains("# HELP boba_requests_total Requests served.\n"));
        assert!(text.contains("# TYPE boba_requests_total counter\n"));
        assert!(text.contains("boba_requests_total{endpoint=\"spmv\"} 42\n"));
        assert!(text.contains("{endpoint=\"a\\\"b\\\\c\"} 1\n"));
        assert!(text.contains("boba_uptime_seconds 1.5\n"));
    }

    #[test]
    fn histogram_is_cumulative_and_ends_in_inf() {
        let h = Histogram::new();
        h.record_us(3); // bucket le 4µs
        h.record_us(3);
        h.record_us(900); // bucket le 1024µs
        let mut p = PromText::new();
        p.family("boba_stage_duration_seconds", "histogram", "Stage time.");
        p.histogram_us("boba_stage_duration_seconds", &[("stage", "reorder")], &h);
        let text = p.render();
        // Cumulative: the 1024µs bucket already includes the two 3µs samples.
        assert!(text.contains("le=\"0.000004\"} 2\n"), "{text}");
        assert!(text.contains("le=\"0.001024\"} 3\n"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 3\n"));
        assert!(text.contains("boba_stage_duration_seconds_sum{stage=\"reorder\"} 0.000906\n"));
        assert!(text.contains("boba_stage_duration_seconds_count{stage=\"reorder\"} 3\n"));
        // Trimmed: no empty top buckets beyond the last occupied one.
        assert!(!text.contains("le=\"0.002048\""));
    }

    #[test]
    fn empty_histogram_still_emits_a_complete_family() {
        let h = Histogram::new();
        let mut p = PromText::new();
        p.histogram_us("x_seconds", &[], &h);
        let text = p.render();
        assert!(text.contains("x_seconds_bucket{le=\"+Inf\"} 0\n"));
        assert!(text.contains("x_seconds_sum 0\n"));
        assert!(text.contains("x_seconds_count 0\n"));
    }

    #[test]
    fn explicit_buckets_for_batch_widths() {
        let widths = [(1.0, 5u64), (2.0, 0), (3.0, 2), (4.0, 0)];
        let mut p = PromText::new();
        p.histogram_buckets("boba_coalesce_batch_width", &[("kind", "spmv")], &widths, 11.0, 7);
        let text = p.render();
        assert!(text.contains("le=\"1\"} 5\n"));
        assert!(text.contains("le=\"2\"} 5\n"));
        assert!(text.contains("le=\"3\"} 7\n"));
        assert!(!text.contains("le=\"4\"}"), "trailing empty bucket trimmed: {text}");
        assert!(text.contains("le=\"+Inf\"} 7\n"));
    }
}
