//! Per-graph append-only write-ahead log for `POST /mutate`.
//!
//! Durability contract: a mutation batch is **acknowledged only after
//! its record is fsync-durable**. Appends from concurrent handlers are
//! batched through a group-commit fsync (one leader syncs for every
//! waiter whose watermark the sync covers), so the per-ack cost under
//! load is a fraction of an fsync.
//!
//! ## Record layout
//!
//! Everything is little-endian. One record per acknowledged batch:
//!
//! | bytes | field                                    |
//! |-------|------------------------------------------|
//! | 4     | `len` — payload length in bytes          |
//! | 8     | `fnv64(payload)` checksum                |
//! | `len` | payload                                  |
//!
//! payload:
//!
//! | bytes | field                                    |
//! |-------|------------------------------------------|
//! | 1     | version (`1`)                            |
//! | 8     | `seq` — record sequence number           |
//! | 4     | `nops`                                   |
//! | 13·n  | ops: `kind:u8, u:u32, v:u32, w:f32` each |
//!
//! Vertex ids are in the **original label space** of the source COO —
//! compaction re-runs the (racy, nondeterministic) BOBA reorder, so
//! artifact-space ids would not survive an epoch swap; original ids do.
//!
//! ## Segments, rotation, retirement
//!
//! The log is a sequence of segment files `<key>.NNNNNN.wal`. The
//! compactor rotates to a fresh segment before materializing a
//! checkpoint, and retires the rotated prefix only after the new
//! `.ckpt.bcoo` has landed via tmp+rename — so at every instant the
//! checkpoint plus the live segments reconstruct the acked state.
//!
//! ## Recovery
//!
//! [`scan`] replays segments in order, verifying length, checksum, and
//! sequence continuity. The first bad record **in the final segment**
//! is a torn tail from a crash mid-write: the tail is truncated
//! (counted as `boba_io_corruption_total{kind="wal-torn-tail"}`) and
//! everything before it — exactly the acked prefix — is replayed.
//! Corruption in a non-final segment is refused loudly: rotation
//! fsyncs, so a damaged interior segment is disk rot, not a crash
//! artifact, and silently dropping acked suffixes would be worse than
//! failing. A shutdown flag is honored between records so Ctrl-C
//! mid-replay exits cleanly **without truncating anything**.

use crate::graph::io::bcoo::fnv64;
use crate::obs::{chaos, corrupt};
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Upsert op kind byte.
pub const OP_UPSERT: u8 = 0;
/// Delete op kind byte.
pub const OP_DELETE: u8 = 1;

const RECORD_VERSION: u8 = 1;
const HEADER_BYTES: usize = 4 + 8;
const PAYLOAD_HEADER_BYTES: usize = 1 + 8 + 4;
const OP_BYTES: usize = 1 + 4 + 4 + 4;
/// Hard cap on ops per record (an 8 MiB request body cannot come close;
/// this bounds what a corrupt length field can make recovery allocate).
pub const MAX_OPS_PER_RECORD: usize = 1 << 20;
const MAX_PAYLOAD_BYTES: usize = PAYLOAD_HEADER_BYTES + MAX_OPS_PER_RECORD * OP_BYTES;

/// One durable mutation op, vertex ids in the original label space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WalOp {
    /// [`OP_UPSERT`] or [`OP_DELETE`].
    pub kind: u8,
    /// Source vertex (original label).
    pub u: u32,
    /// Destination vertex (original label).
    pub v: u32,
    /// Edge weight (ignored for deletes and unweighted graphs).
    pub w: f32,
}

struct Appender {
    file: std::sync::Arc<File>,
    seg: u64,
    next_seq: u64,
    /// Monotonic bytes appended across all segments — the group-commit
    /// watermark space.
    total: u64,
    /// Set after a torn write: the file tail holds garbage, so further
    /// appends would put acked records behind bytes recovery discards.
    poisoned: bool,
}

struct SyncState {
    /// Watermark (in `Appender::total` space) known fsync-durable.
    durable: u64,
    /// True while some thread is the fsync leader.
    syncing: bool,
}

/// An open per-graph write-ahead log.
pub struct Wal {
    dir: PathBuf,
    key: String,
    app: Mutex<Appender>,
    sync: Mutex<SyncState>,
    cv: Condvar,
    /// Lifetime bytes appended (metrics).
    appended: AtomicU64,
}

fn seg_path(dir: &Path, key: &str, seg: u64) -> PathBuf {
    dir.join(format!("{key}.{seg:06}.wal"))
}

/// Checkpoint path for a graph key: `<dir>/<key>.ckpt.bcoo`.
pub fn ckpt_path(dir: &Path, key: &str) -> PathBuf {
    dir.join(format!("{key}.ckpt.bcoo"))
}

/// Meta path for a graph key: `<dir>/<key>.meta.json`.
pub fn meta_path(dir: &Path, key: &str) -> PathBuf {
    dir.join(format!("{key}.meta.json"))
}

/// Filesystem-safe key for a graph id: the sanitized id plus an FNV-64
/// suffix so distinct ids can never collide after sanitization.
pub fn key_for(id: &str) -> String {
    let safe: String = id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    format!("{safe}-{:016x}", fnv64(id.as_bytes()))
}

fn encode_record(seq: u64, ops: &[WalOp]) -> Vec<u8> {
    let payload_len = PAYLOAD_HEADER_BYTES + ops.len() * OP_BYTES;
    let mut rec = Vec::with_capacity(HEADER_BYTES + payload_len);
    rec.extend_from_slice(&(payload_len as u32).to_le_bytes());
    rec.extend_from_slice(&[0u8; 8]); // checksum patched below
    rec.push(RECORD_VERSION);
    rec.extend_from_slice(&seq.to_le_bytes());
    rec.extend_from_slice(&(ops.len() as u32).to_le_bytes());
    for op in ops {
        rec.push(op.kind);
        rec.extend_from_slice(&op.u.to_le_bytes());
        rec.extend_from_slice(&op.v.to_le_bytes());
        rec.extend_from_slice(&op.w.to_le_bytes());
    }
    let sum = fnv64(&rec[HEADER_BYTES..]);
    rec[4..12].copy_from_slice(&sum.to_le_bytes());
    rec
}

/// Copy `N` bytes at `off` into a fixed array. Callers bound-check the
/// slice first, so the length always matches; going through
/// `copy_from_slice` keeps the decode path free of `unwrap()`.
fn le<const N: usize>(b: &[u8], off: usize) -> [u8; N] {
    let mut out = [0u8; N];
    out.copy_from_slice(&b[off..off + N]);
    out
}

fn decode_payload(payload: &[u8]) -> Result<(u64, Vec<WalOp>)> {
    if payload.len() < PAYLOAD_HEADER_BYTES {
        bail!("payload shorter than its header");
    }
    if payload[0] != RECORD_VERSION {
        bail!("unsupported record version {}", payload[0]);
    }
    let seq = u64::from_le_bytes(le(payload, 1));
    let nops = u32::from_le_bytes(le(payload, 9)) as usize;
    if nops > MAX_OPS_PER_RECORD || payload.len() != PAYLOAD_HEADER_BYTES + nops * OP_BYTES {
        bail!("op count {nops} disagrees with payload length {}", payload.len());
    }
    let mut ops = Vec::with_capacity(nops);
    for i in 0..nops {
        let o = PAYLOAD_HEADER_BYTES + i * OP_BYTES;
        ops.push(WalOp {
            kind: payload[o],
            u: u32::from_le_bytes(le(payload, o + 1)),
            v: u32::from_le_bytes(le(payload, o + 5)),
            w: f32::from_le_bytes(le(payload, o + 9)),
        });
    }
    Ok((seq, ops))
}

impl Wal {
    /// Open (creating if absent) the log for `key`, appending to the
    /// segment recovery left behind. `next_seq` and `seg` come from the
    /// [`ScanReport`] (`0` / `0` for a brand-new graph).
    pub fn open(dir: &Path, key: &str, seg: u64, next_seq: u64) -> Result<Wal> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating wal dir {}", dir.display()))?;
        let path = seg_path(dir, key, seg);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening wal segment {}", path.display()))?;
        let existing = file.metadata().map(|m| m.len()).unwrap_or(0);
        Ok(Wal {
            dir: dir.to_path_buf(),
            key: key.to_string(),
            app: Mutex::new(Appender {
                file: std::sync::Arc::new(file),
                seg,
                next_seq,
                total: existing,
                poisoned: false,
            }),
            // Whatever survived recovery is by definition the durable
            // prefix.
            sync: Mutex::new(SyncState { durable: existing, syncing: false }),
            cv: Condvar::new(),
            appended: AtomicU64::new(0),
        })
    }

    /// Directory this log lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Graph key (filename stem) of this log.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Lifetime bytes appended through this handle.
    pub fn appended_bytes(&self) -> u64 {
        self.appended.load(Ordering::Relaxed)
    }

    /// Append one batch and return its sequence number **after** it is
    /// fsync-durable (group-commit: concurrent appenders share one
    /// fsync). Fault points: `wal-io-error` fails before writing,
    /// `wal-torn-write` writes a partial record and poisons the log,
    /// `crash-after-append` aborts the process after durability (the
    /// crash-recovery smoke drives this; the record *is* on disk).
    pub fn append(&self, ops: &[WalOp]) -> Result<u64> {
        if ops.is_empty() {
            bail!("empty mutation batch");
        }
        if ops.len() > MAX_OPS_PER_RECORD {
            bail!("mutation batch of {} ops exceeds {}", ops.len(), MAX_OPS_PER_RECORD);
        }
        let (seq, watermark) = {
            let mut app = self.app.lock().unwrap();
            if app.poisoned {
                bail!("wal is poisoned by an earlier torn write; restart to recover");
            }
            if chaos::should("wal-io-error") {
                bail!("injected wal-io-error");
            }
            let rec = encode_record(app.next_seq, ops);
            if chaos::should("wal-torn-write") {
                // Model a crash mid-write: half the record reaches the
                // disk, then nothing. The appender is poisoned so no
                // later record can land after the garbage tail.
                let torn = &rec[..rec.len() / 2];
                let _ = (&*app.file).write_all(torn);
                let _ = app.file.sync_data();
                app.poisoned = true;
                bail!("injected wal-torn-write ({} of {} bytes)", torn.len(), rec.len());
            }
            (&*app.file)
                .write_all(&rec)
                .with_context(|| format!("appending to wal {}", self.key))?;
            let seq = app.next_seq;
            app.next_seq += 1;
            app.total += rec.len() as u64;
            self.appended.fetch_add(rec.len() as u64, Ordering::Relaxed);
            (seq, app.total)
        };
        self.sync_to(watermark)?;
        if chaos::should("crash-after-append") {
            // The record is durable; an ack may or may not have left the
            // socket — exactly the window crash-equivalence must cover.
            eprintln!("[boba] chaos crash-after-append: aborting after seq {seq}");
            std::process::abort();
        }
        Ok(seq)
    }

    /// Block until everything up to `watermark` is fsync-durable,
    /// electing this thread as the fsync leader when none is active.
    fn sync_to(&self, watermark: u64) -> Result<()> {
        loop {
            {
                let mut st = self.sync.lock().unwrap();
                loop {
                    if st.durable >= watermark {
                        return Ok(());
                    }
                    if !st.syncing {
                        st.syncing = true;
                        break;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            }
            // Leader: snapshot the current segment + watermark, sync it
            // outside both locks. Older segments were fsynced by
            // rotation, so syncing the current file covers `target`.
            let (file, target) = {
                let app = self.app.lock().unwrap();
                (app.file.clone(), app.total)
            };
            let res = file.sync_data();
            let mut st = self.sync.lock().unwrap();
            st.syncing = false;
            if res.is_ok() {
                st.durable = st.durable.max(target);
            }
            self.cv.notify_all();
            res.with_context(|| format!("fsync wal {}", self.key))?;
        }
    }

    /// Fsync and close out the current segment, switch appends to a
    /// fresh one, and return the rotated segment's index. The compactor
    /// calls this before materializing a checkpoint so replay-relevant
    /// suffix records land in segments that survive retirement.
    pub fn rotate(&self) -> Result<u64> {
        let mut app = self.app.lock().unwrap();
        app.file.sync_data().context("fsync before wal rotation")?;
        let old_seg = app.seg;
        let new_seg = old_seg + 1;
        let path = seg_path(&self.dir, &self.key, new_seg);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening wal segment {}", path.display()))?;
        app.file = std::sync::Arc::new(file);
        app.seg = new_seg;
        app.poisoned = false;
        let total = app.total;
        drop(app);
        // Everything written before the rotation point is now durable.
        let mut st = self.sync.lock().unwrap();
        st.durable = st.durable.max(total);
        drop(st);
        self.cv.notify_all();
        Ok(old_seg)
    }

    /// Delete every segment with index `<= seg` (never the current
    /// one). Called only after the checkpoint covering them has landed
    /// via tmp+rename.
    pub fn retire_through(&self, seg: u64) -> Result<()> {
        let current = self.app.lock().unwrap().seg;
        for s in 0..=seg {
            if s == current {
                continue;
            }
            let path = seg_path(&self.dir, &self.key, s);
            if path.exists() {
                fs::remove_file(&path)
                    .with_context(|| format!("retiring wal segment {}", path.display()))?;
            }
        }
        Ok(())
    }
}

/// Result of a recovery [`scan`].
#[derive(Debug, Default)]
pub struct ScanReport {
    /// Every acked op across all surviving records, in append order.
    pub ops: Vec<WalOp>,
    /// Records replayed.
    pub records: u64,
    /// Segment files visited.
    pub segments: u64,
    /// Index of the last (now current) segment.
    pub last_seg: u64,
    /// True when a torn tail was found in the final segment.
    pub torn: bool,
    /// Bytes removed from the final segment (0 unless `repair`).
    pub truncated_bytes: u64,
    /// True when the shutdown flag aborted the scan early — the caller
    /// must not open the log for appending or trust `ops`.
    pub aborted: bool,
    /// The next record sequence number after the surviving prefix.
    pub next_seq: u64,
}

/// List the segment indices present for `key`, ascending.
pub fn list_segments(dir: &Path, key: &str) -> Result<Vec<u64>> {
    let mut segs = Vec::new();
    let prefix = format!("{key}.");
    if !dir.exists() {
        return Ok(segs);
    }
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(rest) = name.strip_prefix(&prefix) {
            if let Some(num) = rest.strip_suffix(".wal") {
                if let Ok(seg) = num.parse::<u64>() {
                    segs.push(seg);
                }
            }
        }
    }
    segs.sort_unstable();
    Ok(segs)
}

/// Replay the log for `key`, validating every record. With `repair`,
/// a torn tail in the final segment is truncated away (and counted as
/// `wal-torn-tail` corruption when bytes are actually removed); without
/// it the scan is read-only. `shutdown` is checked between records: a
/// set flag aborts the scan immediately, leaving every byte on disk
/// untouched.
pub fn scan(dir: &Path, key: &str, shutdown: &AtomicBool, repair: bool) -> Result<ScanReport> {
    let segs = list_segments(dir, key)?;
    let mut report = ScanReport::default();
    let Some(&last) = segs.last() else {
        return Ok(report);
    };
    report.last_seg = last;
    for &seg in &segs {
        let path = seg_path(dir, key, seg);
        let bytes =
            fs::read(&path).with_context(|| format!("reading wal {}", path.display()))?;
        report.segments += 1;
        let mut off = 0usize;
        let bad_at: Option<(usize, &'static str)> = loop {
            if shutdown.load(Ordering::Relaxed) {
                report.aborted = true;
                return Ok(report);
            }
            if off == bytes.len() {
                break None;
            }
            if bytes.len() - off < HEADER_BYTES {
                break Some((off, "short header"));
            }
            let len = u32::from_le_bytes(le(&bytes, off)) as usize;
            if len < PAYLOAD_HEADER_BYTES || len > MAX_PAYLOAD_BYTES {
                break Some((off, "implausible record length"));
            }
            if bytes.len() - off - HEADER_BYTES < len {
                break Some((off, "short payload"));
            }
            let sum = u64::from_le_bytes(le(&bytes, off + 4));
            let payload = &bytes[off + HEADER_BYTES..off + HEADER_BYTES + len];
            if fnv64(payload) != sum {
                break Some((off, "checksum mismatch"));
            }
            let (seq, mut ops) = match decode_payload(payload) {
                Ok(v) => v,
                Err(_) => break Some((off, "malformed payload")),
            };
            if report.records > 0 && seq != report.next_seq {
                break Some((off, "sequence discontinuity"));
            }
            report.ops.append(&mut ops);
            report.records += 1;
            report.next_seq = seq + 1;
            off += HEADER_BYTES + len;
        };
        if let Some((at, why)) = bad_at {
            if seg != last {
                bail!(
                    "wal {}: corrupt record mid-log (segment {seg}, offset {at}: {why}) — \
                     refusing to drop acked records; inspect or remove the log manually",
                    path.display()
                );
            }
            report.torn = true;
            report.truncated_bytes = (bytes.len() - at) as u64;
            if repair && report.truncated_bytes > 0 {
                let f = OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .with_context(|| format!("truncating {}", path.display()))?;
                f.set_len(at as u64)
                    .with_context(|| format!("truncating {}", path.display()))?;
                f.sync_data().ok();
                corrupt::inc("wal-torn-tail");
                eprintln!(
                    "[boba] wal {}: truncated torn tail ({} bytes at offset {at}: {why})",
                    path.display(),
                    report.truncated_bytes
                );
            }
        }
    }
    Ok(report)
}

/// Write (tmp+rename) the meta sidecar that lets recovery rebuild a
/// graph without a request: the id plus its (dataset, scheme) recipe
/// and the mutable epoch the artifact had reached.
pub fn write_meta(
    dir: &Path,
    key: &str,
    id: &str,
    dataset: &str,
    scheme: &str,
    epoch: u64,
) -> Result<()> {
    fs::create_dir_all(dir)?;
    let body = Json::obj(vec![
        ("id", Json::Str(id.to_string())),
        ("dataset", Json::Str(dataset.to_string())),
        ("scheme", Json::Str(scheme.to_string())),
        ("epoch", Json::Num(epoch as f64)),
    ])
    .render();
    let path = meta_path(dir, key);
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    fs::write(&tmp, body.as_bytes())
        .with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, &path).with_context(|| format!("renaming to {}", path.display()))?;
    Ok(())
}

/// One parsed meta sidecar.
#[derive(Debug, Clone)]
pub struct WalMeta {
    /// Graph key (filename stem).
    pub key: String,
    /// Registry graph id.
    pub id: String,
    /// Dataset spec.
    pub dataset: String,
    /// Reorder scheme.
    pub scheme: String,
    /// Mutable epoch at the last meta write.
    pub epoch: u64,
}

/// List every meta sidecar in `dir` (the set of graphs with WAL state
/// to recover), sorted by key for deterministic replay order.
pub fn list_metas(dir: &Path) -> Result<Vec<WalMeta>> {
    let mut metas = Vec::new();
    if !dir.exists() {
        return Ok(metas);
    }
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        let Some(key) = name.strip_suffix(".meta.json") else { continue };
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let field = |k: &str| -> Result<String> {
            json.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("{}: missing field {k:?}", path.display()))
        };
        metas.push(WalMeta {
            key: key.to_string(),
            id: field("id")?,
            dataset: field("dataset")?,
            scheme: field("scheme")?,
            epoch: json.get("epoch").and_then(Json::as_u64).unwrap_or(0),
        });
    }
    metas.sort_by(|a, b| a.key.cmp(&b.key));
    Ok(metas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "boba-wal-{tag}-{}-{:x}",
            std::process::id(),
            fnv64(tag.as_bytes())
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ops_for(seed: u64, n: usize) -> Vec<WalOp> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| WalOp {
                kind: (rng.next_u64() % 2) as u8,
                u: rng.next_u32() % 1000,
                v: rng.next_u32() % 1000,
                w: 1.0,
            })
            .collect()
    }

    static LIVE: AtomicBool = AtomicBool::new(false);

    #[test]
    fn append_scan_roundtrip_across_segments() {
        let dir = tmpdir("roundtrip");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        let mut all = Vec::new();
        for batch in 0..6u64 {
            let ops = ops_for(batch, 3 + batch as usize);
            let seq = wal.append(&ops).unwrap();
            assert_eq!(seq, batch);
            all.extend(ops);
            if batch == 2 {
                assert_eq!(wal.rotate().unwrap(), 0);
            }
        }
        let report = scan(&dir, "g", &LIVE, true).unwrap();
        assert!(!report.torn);
        assert_eq!(report.records, 6);
        assert_eq!(report.segments, 2);
        assert_eq!(report.next_seq, 6);
        assert_eq!(report.ops, all);
        // Reopening appends with continuity.
        drop(wal);
        let wal2 = Wal::open(&dir, "g", report.last_seg, report.next_seq).unwrap();
        wal2.append(&ops_for(99, 2)).unwrap();
        let report2 = scan(&dir, "g", &LIVE, true).unwrap();
        assert_eq!(report2.records, 7);
        assert_eq!(report2.next_seq, 7);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn retire_keeps_current_segment() {
        let dir = tmpdir("retire");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        wal.append(&ops_for(1, 2)).unwrap();
        let old = wal.rotate().unwrap();
        wal.append(&ops_for(2, 2)).unwrap();
        wal.retire_through(old).unwrap();
        assert_eq!(list_segments(&dir, "g").unwrap(), vec![1]);
        let report = scan(&dir, "g", &LIVE, true).unwrap();
        assert_eq!(report.records, 1, "only the post-rotation record survives retirement");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_fault_poisons_and_recovery_keeps_acked_prefix() {
        let _l = chaos::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = tmpdir("torn");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        wal.append(&ops_for(1, 4)).unwrap();
        chaos::set_spec("wal-torn-write:1").unwrap();
        assert!(wal.append(&ops_for(2, 4)).is_err());
        chaos::clear();
        assert!(
            wal.append(&ops_for(3, 4)).is_err(),
            "poisoned appender must refuse further records"
        );
        let before = corrupt::get("wal-torn-tail");
        let report = scan(&dir, "g", &LIVE, true).unwrap();
        assert!(report.torn);
        assert!(report.truncated_bytes > 0);
        assert_eq!(report.records, 1, "exactly the acked prefix survives");
        assert_eq!(report.ops, ops_for(1, 4));
        assert_eq!(corrupt::get("wal-torn-tail"), before + 1);
        // After repair the log is clean again.
        let again = scan(&dir, "g", &LIVE, true).unwrap();
        assert!(!again.torn);
        assert_eq!(again.records, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn io_error_fault_rejects_without_writing() {
        let _l = chaos::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = tmpdir("ioerr");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        wal.append(&ops_for(1, 2)).unwrap();
        chaos::set_spec("wal-io-error:1").unwrap();
        assert!(wal.append(&ops_for(2, 2)).is_err());
        chaos::clear();
        // The failed append left no bytes: the next one continues cleanly.
        wal.append(&ops_for(3, 2)).unwrap();
        let report = scan(&dir, "g", &LIVE, true).unwrap();
        assert!(!report.torn);
        assert_eq!(report.records, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn shutdown_aborts_scan_without_truncating() {
        let dir = tmpdir("shutdown");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        wal.append(&ops_for(1, 3)).unwrap();
        // Leave a torn tail on disk.
        let path = seg_path(&dir, "g", 0);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0xde, 0xad, 0xbe]).unwrap();
        drop(f);
        let len_before = fs::metadata(&path).unwrap().len();
        let stop = AtomicBool::new(true);
        let report = scan(&dir, "g", &stop, true).unwrap();
        assert!(report.aborted);
        assert_eq!(
            fs::metadata(&path).unwrap().len(),
            len_before,
            "aborted scan must not truncate"
        );
        // A live scan then repairs it.
        let report = scan(&dir, "g", &LIVE, true).unwrap();
        assert!(report.torn);
        assert_eq!(report.records, 1);
        assert_eq!(fs::metadata(&path).unwrap().len(), len_before - 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn interior_segment_corruption_is_refused() {
        let dir = tmpdir("interior");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        wal.append(&ops_for(1, 2)).unwrap();
        wal.rotate().unwrap();
        wal.append(&ops_for(2, 2)).unwrap();
        // Flip a byte in the retired (non-final) segment.
        let path = seg_path(&dir, "g", 0);
        let mut bytes = fs::read(&path).unwrap();
        let at = bytes.len() - 1;
        bytes[at] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let err = scan(&dir, "g", &LIVE, true).unwrap_err().to_string();
        assert!(err.contains("mid-log"), "unexpected error: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn meta_roundtrip_and_listing() {
        let dir = tmpdir("meta");
        write_meta(&dir, &key_for("g one"), "g one", "pa:100:4", "boba", 3).unwrap();
        write_meta(&dir, &key_for("g-two"), "g-two", "rmat:10:8", "none", 0).unwrap();
        let metas = list_metas(&dir).unwrap();
        assert_eq!(metas.len(), 2);
        let m = metas.iter().find(|m| m.id == "g one").unwrap();
        assert_eq!(m.dataset, "pa:100:4");
        assert_eq!(m.scheme, "boba");
        assert_eq!(m.epoch, 3);
        assert!(m.key.starts_with("g_one-"), "sanitized key, got {}", m.key);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Satellite: truncate a multi-segment WAL at **every byte offset**
    /// of the final record and assert replay recovers exactly the acked
    /// prefix — no more, no less.
    #[test]
    fn truncation_at_every_final_record_offset_recovers_acked_prefix() {
        const SEED: u64 = 20260808;
        let dir = tmpdir("everybyte");
        let wal = Wal::open(&dir, "g", 0, 0).unwrap();
        let mut batches = Vec::new();
        for batch in 0..5u64 {
            let ops = ops_for(SEED + batch, 2 + batch as usize);
            wal.append(&ops).unwrap();
            batches.push(ops);
            if batch == 1 {
                wal.rotate().unwrap();
            }
        }
        drop(wal);
        let last = seg_path(&dir, "g", 1);
        let full = fs::read(&last).unwrap();
        // Offset (within the final segment) where the final record starts:
        // records 2..=4 live here; the last one is the victim.
        let final_rec_len = {
            let ops = &batches[4];
            HEADER_BYTES + PAYLOAD_HEADER_BYTES + ops.len() * OP_BYTES
        };
        let final_rec_start = full.len() - final_rec_len;
        let work = tmpdir("everybyte-work");
        for cut in final_rec_start..full.len() {
            // Fresh copy of the log with the final segment cut at `cut`.
            for seg in list_segments(&work, "g").unwrap() {
                fs::remove_file(seg_path(&work, "g", seg)).unwrap();
            }
            fs::copy(seg_path(&dir, "g", 0), seg_path(&work, "g", 0)).unwrap();
            fs::write(&seg_path(&work, "g", 1), &full[..cut]).unwrap();
            let report = scan(&work, "g", &LIVE, true).unwrap_or_else(|e| {
                panic!("seed {SEED}, cut offset {cut}: scan failed: {e:#}")
            });
            let want: Vec<WalOp> = batches[..4].iter().flatten().copied().collect();
            assert_eq!(
                report.records, 4,
                "seed {SEED}, cut offset {cut}: expected the 4 acked records, got {}",
                report.records
            );
            assert_eq!(
                report.ops, want,
                "seed {SEED}, cut offset {cut}: replayed ops diverge from the acked prefix"
            );
            assert_eq!(
                report.torn,
                cut != final_rec_start,
                "seed {SEED}, cut offset {cut}: torn flag wrong (a cut exactly at the \
                 record boundary is clean, anything later is torn)"
            );
        }
        // And the uncut log replays everything.
        let report = scan(&dir, "g", &LIVE, false).unwrap();
        let want: Vec<WalOp> = batches.iter().flatten().copied().collect();
        assert_eq!(report.records, 5, "seed {SEED}: uncut log must replay all records");
        assert_eq!(report.ops, want);
        let _ = fs::remove_dir_all(&dir);
        let _ = fs::remove_dir_all(&work);
    }
}
