//! Request routing and query execution: maps the HTTP surface onto the
//! registry and the `algos::` kernels, recording per-endpoint latency.
//!
//! | Route | Effect |
//! |---|---|
//! | `GET  /healthz` | liveness + uptime (never degrades) |
//! | `GET  /readyz` | readiness: 503 while the first prepare runs or the shed ladder is active |
//! | `GET  /stats` | per-endpoint latency histograms + cache counters (`?format=text` for a table) |
//! | `GET  /metrics` | Prometheus text exposition of every counter/gauge/histogram |
//! | `GET  /debug/traces?n=K` | the K most recent stage-span traces, newest first |
//! | `GET/POST /debug/faults` | inspect / arm the deterministic fault-injection table |
//! | `GET  /graphs` | list cached artifacts |
//! | `POST /graphs` | `{"dataset": SPEC, "scheme": NAME}` → prepare (201) or cache hit (200) |
//! | `POST /graphs/{id}/spmv` | one SpMV over the prepared CSR (`{"seed": S}` for a seeded RHS; coalesced) |
//! | `POST /graphs/{id}/pagerank` | PageRank (`{"iters": N}`, default 20; deterministic parallel kernel) |
//! | `POST /graphs/{id}/sssp` | frontier SSSP (`{"source": V}`, default max-degree vertex; coalesced) |
//! | `POST /graphs/{id}/tc` | triangle count (lazy oriented view) |
//! | `POST /graphs/{id}/mutate` | `{"ops": [{"op": "upsert"\|"delete", "u": U, "v": V}]}` → WAL-durable live mutation |
//! | `POST /graphs/{id}/compact` | fold the delta overlay into a new epoch (re-runs BOBA) |
//! | `GET  /graphs/{id}/digest` | label-invariant edge-multiset digest (crash-equivalence observable) |
//! | `POST /query/batch` | `{"id": ID, "queries": [...]}` → heterogeneous batch, SpMV/SSSP tiled into multi-RHS passes |
//!
//! SpMV and SSSP queries route through the per-artifact
//! [`Coalescer`]: concurrent queries against the same prepared graph
//! are answered by one multi-RHS kernel pass. Coalescing never changes
//! an answer (the batched kernels are bit-identical to their one-query
//! forms); responses carry the realized `batch_width` as evidence.
//!
//! Query digests are label-invariant (sums / counts), so the same
//! dataset prepared under different schemes answers identically — the
//! smoke test asserts this against direct `algos::` calls.

use crate::algos::{pagerank, spmm, sssp, tc};
use crate::util::deadline;
use crate::util::timer::Stopwatch;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::admission::{Admission, Reject};
use super::coalesce::{self, BatchOut, BatchQuery, Coalescer};
use super::http::{Request, Response};
use super::json::Json;
use super::live;
use super::registry::{GraphRegistry, PreparedGraph};
use super::stats::{Endpoint, ServerStats};
use super::wal::{WalOp, OP_DELETE, OP_UPSERT};
use crate::graph::delta::DeltaOverlay;

/// Upper bound on `/query/batch` array length (DoS guard; the array is
/// tiled into ≤ [`spmm::MAX_RHS`]-wide kernel passes regardless).
pub const MAX_BATCH_QUERIES: usize = 256;

/// The shared request router.
pub struct Router {
    /// Prepared-artifact cache.
    pub registry: Arc<GraphRegistry>,
    /// Latency/error accounting.
    pub stats: Arc<ServerStats>,
    /// Per-artifact query coalescer (SpMV/SSSP batching).
    pub coalescer: Arc<Coalescer>,
    /// Admission state: rate limits, the in-flight gate, shed ladder.
    pub admission: Arc<Admission>,
    /// Traces slower than this are logged to stderr as one-line JSON
    /// (`None` disables slow-trace logging; set from `--slow-trace-ms`).
    pub slow_trace_ms: Option<f64>,
    /// Deadline applied when the request carries no `x-deadline-ms`
    /// header (`--default-deadline-ms`; `None` = no default).
    pub default_deadline_ms: Option<u64>,
}

impl Router {
    /// New router over shared registry, stats, coalescer, and admission
    /// state.
    pub fn new(
        registry: Arc<GraphRegistry>,
        stats: Arc<ServerStats>,
        coalescer: Arc<Coalescer>,
        admission: Arc<Admission>,
    ) -> Router {
        Router {
            registry,
            stats,
            coalescer,
            admission,
            slow_trace_ms: None,
            default_deadline_ms: None,
        }
    }

    /// Handle one request, recording latency under its endpoint slot.
    ///
    /// Opens a stage-span trace for the request (unless tracing is
    /// disabled): kernel and prepare spans recorded anywhere below the
    /// routing call land in this trace, which is then pushed into the
    /// global ring for `GET /debug/traces`. Introspection endpoints
    /// (`/metrics`, `/debug/traces`, `/stats`, `/healthz`) are traced
    /// but kept out of the ring so scrapes don't evict real work. The
    /// request id is echoed back in an `x-request-id` header.
    pub fn handle(&self, req: &Request) -> Response {
        let sw = Stopwatch::start();
        // Install the request deadline (header wins over the server
        // default) for everything below: admission parking, registry
        // prepare stages, and the kernels' cooperative checkpoints all
        // poll the same thread-local.
        let _deadline = deadline::scope(self.request_deadline(req));
        let guard = crate::obs::begin();
        let (endpoint, mut resp) = self.route(req);
        if let Some(ep) = endpoint {
            self.stats.record(ep, sw.elapsed(), resp.status < 400);
        }
        if guard.is_active() {
            let id = guard.id();
            let name = endpoint.map_or("other", Endpoint::name);
            if let Some(trace) = guard.finish(name, resp.status) {
                let trace = Arc::new(trace);
                let introspection = matches!(
                    endpoint,
                    None | Some(
                        Endpoint::Metrics
                            | Endpoint::Traces
                            | Endpoint::Stats
                            | Endpoint::Healthz
                            | Endpoint::Readyz
                    )
                );
                if !introspection {
                    crate::obs::ring::global().push(Arc::clone(&trace));
                }
                if let Some(th) = self.slow_trace_ms {
                    if trace.total_us as f64 / 1e3 >= th {
                        eprintln!("{}", trace.render_line());
                    }
                }
                resp = resp.with_header("x-request-id", format!("r-{id}"));
            }
        }
        resp
    }

    fn route(&self, req: &Request) -> (Option<Endpoint>, Response) {
        let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", []) => (None, Response::text(200, USAGE)),
            ("GET", ["healthz"]) => (Some(Endpoint::Healthz), self.healthz()),
            ("GET", ["readyz"]) => (Some(Endpoint::Readyz), self.readyz()),
            ("GET", ["stats"]) => (Some(Endpoint::Stats), self.stats_page(req)),
            ("GET", ["metrics"]) => (Some(Endpoint::Metrics), self.metrics_page()),
            ("GET", ["debug", "traces"]) => (Some(Endpoint::Traces), self.traces_page(req)),
            ("GET", ["debug", "faults"]) => {
                (None, Response::json(200, crate::obs::chaos::snapshot_json().render()))
            }
            ("POST", ["debug", "faults"]) => (None, self.set_faults(req)),
            ("GET", ["graphs"]) => (Some(Endpoint::List), self.list()),
            ("POST", ["graphs"]) => (
                Some(Endpoint::Ingest),
                self.admitted(req, Endpoint::Ingest, |r| self.ingest(r)),
            ),
            ("POST", ["query", "batch"]) => (
                Some(Endpoint::Batch),
                self.admitted(req, Endpoint::Batch, |r| self.query_batch(r)),
            ),
            ("POST", ["graphs", id, "mutate"]) => (
                Some(Endpoint::Mutate),
                self.admitted(req, Endpoint::Mutate, |r| self.mutate(id, r)),
            ),
            ("POST", ["graphs", id, "compact"]) => (
                Some(Endpoint::Mutate),
                self.admitted(req, Endpoint::Mutate, |_| self.compact_now(id)),
            ),
            ("GET", ["graphs", id, "digest"]) => (Some(Endpoint::Mutate), self.digest_page(id)),
            ("POST", ["graphs", id, query]) => match Endpoint::query_from(query) {
                Some(ep) => (Some(ep), self.admitted(req, ep, |r| self.query(id, ep, r))),
                None => (
                    None,
                    Response::error(404, &format!("unknown query {query:?} (spmv|pagerank|sssp|tc)")),
                ),
            },
            ("GET", ["debug", ..]) => (None, Response::error(404, "no such route")),
            (
                _,
                ["healthz" | "readyz" | "stats" | "metrics" | "debug" | "graphs" | "query", ..],
            ) => (None, Response::error(405, "method not allowed")),
            _ => (None, Response::error(404, "no such route")),
        }
    }

    /// Deadline for this request: `x-deadline-ms` header if present
    /// (capped at 1 h; `0` means the budget is already spent), else the
    /// server default.
    fn request_deadline(&self, req: &Request) -> Option<Instant> {
        let ms = req
            .header("x-deadline-ms")
            .and_then(|v| v.trim().parse::<u64>().ok())
            .or(self.default_deadline_ms)?;
        Some(Instant::now() + Duration::from_millis(ms.min(3_600_000)))
    }

    /// Run a work endpoint behind the admission ladder (rate → shed →
    /// in-flight gate; see [`super::admission`]) and the dequeue-time
    /// deadline check. Introspection endpoints bypass this — a loaded
    /// server must stay observable.
    fn admitted(
        &self,
        req: &Request,
        ep: Endpoint,
        f: impl FnOnce(&Request) -> Response,
    ) -> Response {
        let tenant = req.header("x-tenant").unwrap_or(super::admission::DEFAULT_TENANT);
        // The shed ladder refuses the kinds a saturated server cannot
        // afford to start: whole-graph kernels (TC's oriented view,
        // PageRank's iteration loop) queue behind nothing.
        let expensive = matches!(ep, Endpoint::Tc | Endpoint::Pagerank);
        let _permit = match self.admission.admit(tenant, expensive) {
            Ok(p) => p,
            Err(r) => return reject_response(r),
        };
        // Dequeue-time deadline check: the request may have parked in
        // the admission queue past its budget.
        if deadline::expired() {
            self.admission.note_deadline_hit();
            return deadline_response("deadline exceeded while queued for admission");
        }
        let resp = f(req);
        // The permit drops here: the in-flight slot covers the whole
        // handler, including coalesce parking and prepare joins.
        resp
    }

    /// `POST /debug/faults`: arm the fault-injection table from
    /// `{"spec": "..."}` (see [`crate::obs::chaos`] for the grammar; an
    /// empty spec disarms). Test-harness surface — answers with the
    /// armed table.
    fn set_faults(&self, req: &Request) -> Response {
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
        };
        let spec = match body.get("spec").and_then(Json::as_str) {
            Some(s) => s,
            None => return Response::error(422, "body must carry {\"spec\": \"...\"}"),
        };
        match crate::obs::chaos::set_spec(spec) {
            Ok(()) => Response::json(200, crate::obs::chaos::snapshot_json().render()),
            Err(e) => Response::error(422, &format!("{e:#}")),
        }
    }

    /// `GET /readyz`: readiness, as opposed to `/healthz` liveness. 503
    /// with the degradation reasons while the registry is running its
    /// first prepare (nothing to serve yet) or admission pressure has
    /// the shed ladder active; 200 otherwise.
    fn readyz(&self) -> Response {
        let mut reasons: Vec<Json> = Vec::new();
        // WAL replay in progress: artifacts exist but their mutation
        // suffixes are not applied yet — serving now could answer from
        // a pre-crash state, so readiness degrades until replay drains.
        if self.registry.recovering() > 0 {
            reasons.push(Json::Str("recovering".into()));
        }
        if self.registry.mid_first_prepare() {
            reasons.push(Json::Str("first-prepare".into()));
        }
        if self.admission.pressured() {
            reasons.push(Json::Str("shedding".into()));
        }
        let ready = reasons.is_empty();
        Response::json(
            if ready { 200 } else { 503 },
            Json::obj(vec![
                ("status", Json::Str(if ready { "ready" } else { "degraded" }.into())),
                ("reasons", Json::Arr(reasons)),
                ("inflight", Json::Num(self.admission.inflight() as f64)),
            ])
            .render(),
        )
    }

    fn healthz(&self) -> Response {
        Response::json(
            200,
            Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("uptime_ms", Json::Num(self.stats.uptime_ms())),
                ("graphs", Json::Num(self.registry.len() as f64)),
            ])
            .render(),
        )
    }

    fn stats_page(&self, req: &Request) -> Response {
        if req.query.contains("format=text") {
            return Response::text(200, self.stats.render_text());
        }
        let mut body = self.stats.to_json().into_obj_pairs();
        body.push(("registry".to_string(), self.registry.stats_json()));
        body.push(("coalescer".to_string(), self.coalescer.stats_json()));
        body.push(("admission".to_string(), self.admission.to_json()));
        let pool = crate::parallel::pool::snapshot();
        body.push((
            "pool".to_string(),
            Json::obj(vec![
                ("threads", Json::Num(pool.spawned as f64)),
                ("active", Json::Num(pool.active as f64)),
                ("parked", Json::Num(pool.parked as f64)),
                ("dispatches", Json::Num(pool.dispatches as f64)),
            ]),
        ));
        Response::json(200, Json::Obj(body).render())
    }

    /// `GET /metrics`: the whole observable state of the process in
    /// Prometheus text exposition format (version 0.0.4), hand-rolled
    /// via [`crate::obs::metrics::PromText`]. Durations are exposed in
    /// seconds (Prometheus base units); the log₂-µs histogram buckets
    /// become cumulative `le` series. Scrapers — including our own
    /// loadgen `--scrape-metrics` — diff two snapshots to recover
    /// server-side latency percentiles and stage breakdowns.
    fn metrics_page(&self) -> Response {
        use crate::obs::metrics::PromText;
        let mut p = PromText::new();

        p.family("boba_uptime_seconds", "gauge", "Seconds since the server started.");
        p.value("boba_uptime_seconds", &[], self.stats.uptime_ms() / 1e3);

        p.family(
            "boba_requests_total",
            "counter",
            "Requests handled, by endpoint (including errors).",
        );
        for ep in Endpoint::ALL {
            let h = self.stats.histogram(ep);
            p.value("boba_requests_total", &[("endpoint", ep.name())], h.count() as f64);
        }
        p.family(
            "boba_request_errors_total",
            "counter",
            "Requests answered with a 4xx/5xx status, by endpoint.",
        );
        for ep in Endpoint::ALL {
            p.value(
                "boba_request_errors_total",
                &[("endpoint", ep.name())],
                self.stats.errors(ep) as f64,
            );
        }
        p.family(
            "boba_request_duration_seconds",
            "histogram",
            "Request latency, by endpoint.",
        );
        for ep in Endpoint::ALL {
            let h = self.stats.histogram(ep);
            p.histogram_us("boba_request_duration_seconds", &[("endpoint", ep.name())], h);
        }

        p.family("boba_registry_graphs", "gauge", "Prepared graphs resident in the cache.");
        p.value("boba_registry_graphs", &[], self.registry.len() as f64);
        p.family("boba_registry_capacity", "gauge", "Registry LRU capacity.");
        p.value("boba_registry_capacity", &[], self.registry.capacity() as f64);
        p.family("boba_registry_hits_total", "counter", "Registry cache hits.");
        p.value("boba_registry_hits_total", &[], self.registry.hits() as f64);
        p.family("boba_registry_misses_total", "counter", "Registry cache misses.");
        p.value("boba_registry_misses_total", &[], self.registry.misses() as f64);
        p.family("boba_registry_evictions_total", "counter", "Prepared graphs evicted by the LRU.");
        p.value("boba_registry_evictions_total", &[], self.registry.evictions() as f64);
        p.family("boba_registry_prepares_total", "counter", "Cold prepare pipelines executed.");
        p.value("boba_registry_prepares_total", &[], self.registry.prepares() as f64);

        // Family header emitted unconditionally (dashboards key on it);
        // samples only for artifacts carrying a compressed variant
        // (`serve --format`).
        p.family(
            "boba_format_bytes_per_edge",
            "gauge",
            "Column-stream bytes per edge of each artifact's compressed kernel format.",
        );
        for g in self.registry.list() {
            if let Some(f) = &g.format {
                p.value(
                    "boba_format_bytes_per_edge",
                    &[("graph", g.id.as_str()), ("format", f.name())],
                    f.bytes_per_edge(),
                );
            }
        }

        let pool = crate::parallel::pool::snapshot();
        p.family(
            "boba_pool_threads",
            "gauge",
            "Worker-pool threads by state (active = inside a parallel region).",
        );
        p.value("boba_pool_threads", &[("state", "active")], pool.active as f64);
        p.value("boba_pool_threads", &[("state", "parked")], pool.parked as f64);
        p.family("boba_pool_threads_spawned", "gauge", "Worker threads spawned so far.");
        p.value("boba_pool_threads_spawned", &[], pool.spawned as f64);
        p.family("boba_pool_dispatches_total", "counter", "Parallel regions dispatched to the pool.");
        p.value("boba_pool_dispatches_total", &[], pool.dispatches as f64);

        p.family(
            "boba_coalesce_batches_total",
            "counter",
            "Kernel passes executed by the coalescer, by query kind.",
        );
        p.value("boba_coalesce_batches_total", &[("kind", "spmv")], self.coalescer.spmv_widths().batches() as f64);
        p.value("boba_coalesce_batches_total", &[("kind", "sssp")], self.coalescer.sssp_widths().batches() as f64);
        p.family(
            "boba_coalesce_queries_total",
            "counter",
            "Queries answered through the coalescer, by kind.",
        );
        p.value("boba_coalesce_queries_total", &[("kind", "spmv")], self.coalescer.spmv_widths().queries() as f64);
        p.value("boba_coalesce_queries_total", &[("kind", "sssp")], self.coalescer.sssp_widths().queries() as f64);
        p.family("boba_coalesce_groups", "gauge", "Live batching groups (one per hot artifact/kind).");
        p.value("boba_coalesce_groups", &[], self.coalescer.group_count() as f64);
        p.family(
            "boba_coalesce_batch_width",
            "histogram",
            "Realized batch width (queries per kernel pass), by kind.",
        );
        for (kind, w) in
            [("spmv", self.coalescer.spmv_widths()), ("sssp", self.coalescer.sssp_widths())]
        {
            let counts = w.bucket_counts();
            let buckets: Vec<(f64, u64)> =
                counts.iter().enumerate().map(|(i, &c)| ((i + 1) as f64, c)).collect();
            let (mut sum, mut count) = (0.0, 0);
            for (i, &c) in counts.iter().enumerate() {
                sum += (i + 1) as f64 * c as f64;
                count += c;
            }
            p.histogram_buckets(
                "boba_coalesce_batch_width",
                &[("kind", kind)],
                &buckets,
                sum,
                count,
            );
        }

        p.family(
            "boba_stage_duration_seconds",
            "histogram",
            "Wall time per named pipeline stage or kernel span.",
        );
        for (name, h) in crate::obs::stage_histograms() {
            p.histogram_us("boba_stage_duration_seconds", &[("stage", name)], &h);
        }

        p.family(
            "boba_process_resident_memory_bytes",
            "gauge",
            "Resident set size (VmRSS) of this process.",
        );
        p.value(
            "boba_process_resident_memory_bytes",
            &[],
            crate::bench::machine::rss_bytes().unwrap_or(0) as f64,
        );
        p.family(
            "boba_process_resident_memory_peak_bytes",
            "gauge",
            "Peak resident set size (VmHWM) of this process.",
        );
        p.value(
            "boba_process_resident_memory_peak_bytes",
            &[],
            crate::bench::machine::rss_peak_bytes().unwrap_or(0) as f64,
        );

        p.family("boba_traces_total", "counter", "Request traces recorded into the debug ring.");
        p.value("boba_traces_total", &[], crate::obs::ring::global().pushed() as f64);

        p.family(
            "boba_inflight",
            "gauge",
            "Requests currently executing under the admission gate.",
        );
        p.value("boba_inflight", &[], self.admission.inflight() as f64);
        // Family header emitted unconditionally; per-(tenant, reason)
        // samples appear as rejections happen (cardinality is bounded
        // by the admission module's tenant cap).
        p.family(
            "boba_admission_rejected_total",
            "counter",
            "Requests refused admission, by tenant and reason.",
        );
        for (tenant, reason, n) in self.admission.rejected_snapshot() {
            p.value(
                "boba_admission_rejected_total",
                &[("tenant", tenant.as_str()), ("reason", reason)],
                n as f64,
            );
        }
        p.family(
            "boba_deadline_exceeded_total",
            "counter",
            "Admitted requests that ran out of deadline at a checkpoint.",
        );
        p.value("boba_deadline_exceeded_total", &[], self.admission.deadline_hits() as f64);

        let live = self.registry.live_list();
        p.family(
            "boba_mutations_total",
            "counter",
            "Mutation ops durably acked across live graphs.",
        );
        p.value("boba_mutations_total", &[], live.iter().map(|l| l.ops()).sum::<u64>() as f64);
        p.family(
            "boba_compactions_total",
            "counter",
            "Background compactions completed (BOBA re-run + epoch swap).",
        );
        p.value("boba_compactions_total", &[], self.registry.compactions() as f64);
        p.family(
            "boba_delta_entries",
            "gauge",
            "Uncompacted delta-overlay entries per live graph.",
        );
        for l in &live {
            p.value("boba_delta_entries", &[("graph", l.id.as_str())], l.delta_entries() as f64);
        }
        p.family(
            "boba_recovering",
            "gauge",
            "WAL-backed graphs still replaying after restart.",
        );
        p.value("boba_recovering", &[], self.registry.recovering() as f64);
        // All kinds emitted even at zero so dashboards can alert on
        // first increment without waiting for the series to appear.
        p.family(
            "boba_io_corruption_total",
            "counter",
            "Storage corruption events detected and contained, by kind.",
        );
        for (kind, n) in crate::obs::corrupt::snapshot() {
            p.value("boba_io_corruption_total", &[("kind", kind)], n as f64);
        }

        Response::text_with_type(200, "text/plain; version=0.0.4", p.render())
    }

    /// `GET /debug/traces?n=K`: the K most recent request traces
    /// (default 32, capped at the ring capacity), newest first, as a
    /// JSON array of span trees.
    fn traces_page(&self, req: &Request) -> Response {
        let n = req
            .query
            .split('&')
            .find_map(|kv| kv.strip_prefix("n="))
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(32);
        let ring = crate::obs::ring::global();
        let rows: Vec<Json> = ring.recent(n).iter().map(|t| t.to_json()).collect();
        Response::json(
            200,
            Json::obj(vec![
                ("enabled", Json::Bool(crate::obs::enabled())),
                ("capacity", Json::Num(ring.capacity() as f64)),
                ("recorded", Json::Num(ring.pushed() as f64)),
                ("traces", Json::Arr(rows)),
            ])
            .render(),
        )
    }

    fn list(&self) -> Response {
        let rows: Vec<Json> = self
            .registry
            .list()
            .iter()
            .map(|g| {
                let mut pairs = g.to_json().into_obj_pairs();
                if let Some(l) = self.registry.live_graph(&g.id) {
                    pairs.push(("live".to_string(), l.to_json()));
                }
                Json::Obj(pairs)
            })
            .collect();
        Response::json(200, Json::Arr(rows).render())
    }

    /// `POST /graphs/{id}/mutate`: apply a batch of live mutations.
    /// Body: `{"ops": [{"op": "upsert"|"delete", "u": U, "v": V,
    /// "w": W?}, ...]}` with vertex ids in the **original** label space
    /// (the ids the dataset was ingested with — the WAL stores these so
    /// replay survives the nondeterministic reorder). The 200 reply is
    /// the durability ack: the batch's WAL record is fsynced before the
    /// overlay is touched.
    fn mutate(&self, id: &str, req: &Request) -> Response {
        let graph = match self.registry.get(id) {
            Some(g) => g,
            None => {
                return Response::error(
                    404,
                    &format!("no prepared graph {id:?} (POST /graphs first)"),
                )
            }
        };
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
        };
        let ops = match parse_ops(&body, graph.n()) {
            Ok(o) => o,
            Err(e) => return Response::error(422, &format!("{e:#}")),
        };
        let live = match self.registry.live_for(&graph) {
            Ok(l) => l,
            Err(e) => return Response::error(503, &format!("{e:#}")),
        };
        match crate::obs::span("mutate.append", || live.mutate(&ops)) {
            Ok(ack) => {
                live::maybe_compact_bg(&self.registry, &live);
                Response::json(
                    200,
                    Json::obj(vec![
                        ("id", Json::Str(live.id.clone())),
                        ("seq", Json::Num(ack.seq as f64)),
                        ("epoch", Json::Num(ack.epoch as f64)),
                        ("ops", Json::Num(ack.ops as f64)),
                        ("delta_entries", Json::Num(ack.delta_entries as f64)),
                        ("durable", Json::Bool(true)),
                    ])
                    .render(),
                )
            }
            // Ops were validated above, so a mutate error here is the
            // WAL refusing durability (I/O error, poisoned tail) — a
            // server-side failure, not a bad request.
            Err(e) => Response::error(503, &format!("{e:#}")),
        }
    }

    /// `POST /graphs/{id}/compact`: synchronously fold the overlay into
    /// a new epoch (the background compactor runs this same routine when
    /// the overlay crosses `--compact-threshold`).
    fn compact_now(&self, id: &str) -> Response {
        let graph = match self.registry.get(id) {
            Some(g) => g,
            None => {
                return Response::error(
                    404,
                    &format!("no prepared graph {id:?} (POST /graphs first)"),
                )
            }
        };
        let live = match self.registry.live_for(&graph) {
            Ok(l) => l,
            Err(e) => return Response::error(503, &format!("{e:#}")),
        };
        match crate::obs::span("compact", || live::compact(&self.registry, &live)) {
            Ok(ran) => Response::json(
                200,
                Json::obj(vec![
                    ("id", Json::Str(live.id.clone())),
                    ("compacted", Json::Bool(ran)),
                    ("epoch", Json::Num(live.epoch() as f64)),
                    ("delta_entries", Json::Num(live.delta_entries() as f64)),
                ])
                .render(),
            ),
            Err(e) => Response::error(503, &format!("{e:#}")),
        }
    }

    /// `GET /graphs/{id}/digest`: the label-invariant edge-multiset
    /// digest of base ⊕ delta in the original label space — equal
    /// across schemes, epochs, restarts, and crash recoveries iff the
    /// logical graphs are equal (the crash-equivalence observable).
    fn digest_page(&self, id: &str) -> Response {
        let graph = match self.registry.get(id) {
            Some(g) => g,
            None => {
                return Response::error(
                    404,
                    &format!("no prepared graph {id:?} (POST /graphs first)"),
                )
            }
        };
        let (digest, epoch, entries) = match self.registry.live_graph(id) {
            Some(l) => (l.digest(), l.epoch(), l.delta_entries()),
            None => (live::digest(&graph, &DeltaOverlay::empty(graph.n())), graph.epoch, 0),
        };
        Response::json(
            200,
            Json::obj(vec![
                ("id", Json::Str(graph.id.clone())),
                ("digest", Json::Str(format!("{digest:016x}"))),
                ("epoch", Json::Num(epoch as f64)),
                ("delta_entries", Json::Num(entries as f64)),
            ])
            .render(),
        )
    }

    fn ingest(&self, req: &Request) -> Response {
        let body = if req.body.is_empty() {
            Json::Obj(Vec::new())
        } else {
            match Json::parse(&req.body_str()) {
                Ok(v) => v,
                Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
            }
        };
        let dataset = match body.get("dataset").and_then(Json::as_str) {
            Some(d) => d.to_string(),
            None => return Response::error(422, "body must carry {\"dataset\": \"...\"}"),
        };
        let scheme = body
            .get("scheme")
            .and_then(Json::as_str)
            .unwrap_or("boba")
            .to_string();
        match self.registry.get_or_prepare(&dataset, &scheme) {
            Ok((g, cached)) => {
                let mut pairs = g.to_json().into_obj_pairs();
                pairs.push(("cached".to_string(), Json::Bool(cached)));
                let status = if cached { 200 } else { 201 };
                Response::json(status, Json::Obj(pairs).render())
            }
            Err(e) => {
                // A prepare aborted at a deadline checkpoint (or a
                // waiter that detached from an in-flight prepare) is a
                // timeout, not a bad request.
                if deadline::expired() {
                    self.admission.note_deadline_hit();
                    return deadline_response(&format!("{e:#}"));
                }
                Response::error(422, &format!("{e:#}"))
            }
        }
    }

    fn query(&self, id: &str, ep: Endpoint, req: &Request) -> Response {
        let graph = match self.registry.get(id) {
            Some(g) => g,
            None => {
                return Response::error(
                    404,
                    &format!("no prepared graph {id:?} (POST /graphs first)"),
                )
            }
        };
        let body = if req.body.is_empty() {
            Json::Obj(Vec::new())
        } else {
            match Json::parse(&req.body_str()) {
                Ok(v) => v,
                Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
            }
        };
        // Pre-dispatch deadline check: don't start a kernel whose
        // answer nobody is waiting for.
        if deadline::expired() {
            self.admission.note_deadline_hit();
            return deadline_response("deadline exceeded before kernel dispatch");
        }
        // Live overlay: when this artifact has unfolded mutations, run
        // the merged (base ⊕ delta) kernels over an atomic snapshot —
        // bypassing the coalescer, whose batches are keyed to frozen
        // artifact instances. The snapshot's base may be a newer epoch
        // than `graph` if a compaction just swapped; either way the
        // query sees one consistent (base, delta) pair end to end.
        let overlay = self.registry.live_graph(&graph.id).and_then(|l| {
            let (base, delta, _) = l.view();
            (!delta.is_empty()).then_some((base, delta))
        });
        let sw = Stopwatch::start();
        let result = match (&overlay, ep) {
            (Some((base, delta)), _) => run_merged_query(base, delta, ep, &body),
            // SpMV/SSSP go through the coalescer: concurrent queries
            // against this artifact share one multi-RHS kernel pass.
            (None, Endpoint::Spmv | Endpoint::Sssp) => parse_coalescable(&graph, ep, &body)
                .and_then(|q| {
                    // The kernel span lands in the batch leader's trace;
                    // followers record only their coalesce wait here.
                    let (out, width) =
                        crate::obs::span("coalesce.submit", || self.coalescer.submit(&graph, q))?;
                    Ok(coalesced_json(q, out, width))
                }),
            (None, _) => run_query(&graph, ep, &body),
        };
        // Post-kernel deadline check: an iterative kernel that bailed at
        // a cooperative checkpoint returns a partial result — map it to
        // 504 rather than serving it as an answer.
        if deadline::expired() {
            self.admission.note_deadline_hit();
            return deadline_response("deadline exceeded during kernel execution");
        }
        let mut pairs = match result {
            Ok(j) => j.into_obj_pairs(),
            Err(e) => return Response::error(422, &format!("{e:#}")),
        };
        graph.queries.fetch_add(1, Ordering::Relaxed);
        pairs.insert(0, ("id".to_string(), Json::Str(graph.id.clone())));
        pairs.insert(1, ("query".to_string(), Json::Str(ep.name().into())));
        pairs.push(("ms".to_string(), Json::Num(sw.ms())));
        Response::json(200, Json::Obj(pairs).render())
    }

    /// `POST /query/batch`: execute a heterogeneous query array against
    /// one prepared artifact. SpMV entries are tiled into
    /// ≤ [`spmm::MAX_RHS`]-wide [`coalesce::run_spmv_tile`] passes and
    /// SSSP entries into [`coalesce::run_sssp_tile`] scans (each tile is
    /// one edge-stream); identical PageRank/TC entries are deduplicated
    /// and computed once. Results come back in input order.
    fn query_batch(&self, req: &Request) -> Response {
        let body = match Json::parse(&req.body_str()) {
            Ok(v) => v,
            Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
        };
        let id = match body.get("id").and_then(Json::as_str) {
            Some(i) => i.to_string(),
            None => return Response::error(422, "body must carry {\"id\": \"dataset@scheme\"}"),
        };
        let graph = match self.registry.get(&id) {
            Some(g) => g,
            None => {
                return Response::error(
                    404,
                    &format!("no prepared graph {id:?} (POST /graphs first)"),
                )
            }
        };
        let entries = match body.get("queries") {
            Some(Json::Arr(items)) if !items.is_empty() => items,
            Some(Json::Arr(_)) => return Response::error(422, "queries array is empty"),
            _ => return Response::error(422, "body must carry {\"queries\": [...]}"),
        };
        if entries.len() > MAX_BATCH_QUERIES {
            return Response::error(
                422,
                &format!("{} queries exceed the {MAX_BATCH_QUERIES} per-batch cap", entries.len()),
            );
        }
        // Validate every entry before executing any (a bad index fails
        // the whole batch with its position named).
        enum Plan {
            Spmv { seed: Option<u64> },
            Sssp { source: u32 },
            Direct(Endpoint, Json),
        }
        let mut plans = Vec::with_capacity(entries.len());
        for (i, q) in entries.iter().enumerate() {
            let name = match q.get("query").and_then(Json::as_str) {
                Some(n) => n,
                None => {
                    return Response::error(422, &format!("queries[{i}] missing \"query\" name"))
                }
            };
            let ep = match Endpoint::query_from(name) {
                Some(ep) => ep,
                None => {
                    return Response::error(
                        422,
                        &format!("queries[{i}]: unknown query {name:?} (spmv|pagerank|sssp|tc)"),
                    )
                }
            };
            match parse_coalescable(&graph, ep, q) {
                Ok(BatchQuery::Spmv { seed }) => plans.push(Plan::Spmv { seed }),
                Ok(BatchQuery::Sssp { source }) => plans.push(Plan::Sssp { source }),
                Err(e) if matches!(ep, Endpoint::Spmv | Endpoint::Sssp) => {
                    return Response::error(422, &format!("queries[{i}]: {e:#}"))
                }
                _ => {
                    // Direct kinds validate here too, so no kernel pass
                    // (or width-histogram entry) ever runs for a batch
                    // that is doomed to 422.
                    if ep == Endpoint::Pagerank {
                        let iters = q.get("iters").and_then(Json::as_u64).unwrap_or(20);
                        if !(1..=10_000).contains(&iters) {
                            return Response::error(
                                422,
                                &format!("queries[{i}]: iters must be in 1..=10000"),
                            );
                        }
                    }
                    plans.push(Plan::Direct(ep, q.clone()))
                }
            }
        }
        let sw = Stopwatch::start();
        // Live overlay: merged kernels don't coalesce (tiles are keyed
        // to frozen artifact instances), so batch members run one by
        // one against a single atomic (base, delta) snapshot — every
        // member of the batch sees the same graph version.
        let overlay = self.registry.live_graph(&graph.id).and_then(|l| {
            let (base, delta, _) = l.view();
            (!delta.is_empty()).then_some((base, delta))
        });
        if let Some((base, delta)) = overlay {
            let mut rows = Vec::with_capacity(plans.len());
            for (i, plan) in plans.iter().enumerate() {
                if deadline::expired() {
                    self.admission.note_deadline_hit();
                    return deadline_response("deadline exceeded between batch members");
                }
                let (ep, body) = match plan {
                    Plan::Spmv { seed } => (
                        Endpoint::Spmv,
                        Json::obj(
                            seed.map(|s| vec![("seed", Json::Num(s as f64))]).unwrap_or_default(),
                        ),
                    ),
                    Plan::Sssp { source } => {
                        (Endpoint::Sssp, Json::obj(vec![("source", Json::Num(*source as f64))]))
                    }
                    Plan::Direct(ep, q) => (*ep, q.clone()),
                };
                match run_merged_query(&base, &delta, ep, &body) {
                    Ok(v) => rows.push(with_query_name(ep.name(), v)),
                    Err(e) => return Response::error(422, &format!("queries[{i}]: {e:#}")),
                }
            }
            let count = plans.len();
            graph.queries.fetch_add(count as u64, Ordering::Relaxed);
            return Response::json(
                200,
                Json::obj(vec![
                    ("id", Json::Str(graph.id.clone())),
                    ("count", Json::Num(count as f64)),
                    ("results", Json::Arr(rows)),
                    ("ms", Json::Num(sw.ms())),
                ])
                .render(),
            );
        }
        // Tile the homogeneous groups: one kernel pass per tile. The
        // slot index carries its plan's payload, so the tile loops
        // below need no (panicking) re-match against `plans`.
        let spmv_idx: Vec<(usize, Option<u64>)> = plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| match p {
                Plan::Spmv { seed } => Some((i, *seed)),
                _ => None,
            })
            .collect();
        let sssp_idx: Vec<(usize, u32)> = plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| match p {
                Plan::Sssp { source } => Some((i, *source)),
                _ => None,
            })
            .collect();
        let mut results: Vec<Option<Json>> = (0..plans.len()).map(|_| None).collect();
        for tile in spmv_idx.chunks(spmm::MAX_RHS) {
            // Cooperative checkpoint between batch members: a deadline
            // that lapsed mid-batch fails the whole request (batches
            // are all-or-nothing) without running the remaining tiles.
            if deadline::expired() {
                self.admission.note_deadline_hit();
                return deadline_response("deadline exceeded between batch tiles");
            }
            let seeds: Vec<Option<u64>> = tile.iter().map(|&(_, seed)| seed).collect();
            self.coalescer.spmv_widths().record(tile.len());
            for (&(i, seed), digest) in tile.iter().zip(coalesce::run_spmv_tile(&graph, &seeds)) {
                let q = BatchQuery::Spmv { seed };
                results[i] = Some(with_query_name(
                    "spmv",
                    coalesced_json(q, BatchOut::Spmv { digest }, tile.len()),
                ));
            }
        }
        for tile in sssp_idx.chunks(sssp::MAX_SOURCES) {
            if deadline::expired() {
                self.admission.note_deadline_hit();
                return deadline_response("deadline exceeded between batch tiles");
            }
            let sources: Vec<u32> = tile.iter().map(|&(_, source)| source).collect();
            self.coalescer.sssp_widths().record(tile.len());
            for (&(i, source), (digest, reached)) in
                tile.iter().zip(coalesce::run_sssp_tile(&graph, &sources))
            {
                let q = BatchQuery::Sssp { source };
                results[i] = Some(with_query_name(
                    "sssp",
                    coalesced_json(q, BatchOut::Sssp { digest, reached }, tile.len()),
                ));
            }
        }
        // Remaining kinds: identical queries collapse to one execution.
        let mut memo: Vec<(String, Json)> = Vec::new();
        for (i, plan) in plans.iter().enumerate() {
            if let Plan::Direct(ep, q) = plan {
                if deadline::expired() {
                    self.admission.note_deadline_hit();
                    return deadline_response("deadline exceeded between batch members");
                }
                let key = format!("{}|{}", ep.name(), q.render());
                let cached = memo.iter().find(|(k, _)| *k == key).map(|(_, v)| v.clone());
                let out = match cached {
                    Some(v) => v,
                    None => match run_query(&graph, *ep, q) {
                        Ok(v) => {
                            memo.push((key, v.clone()));
                            v
                        }
                        Err(e) => {
                            return Response::error(422, &format!("queries[{i}]: {e:#}"))
                        }
                    },
                };
                results[i] = Some(with_query_name(ep.name(), out));
            }
            // Spmv/Sssp slots were filled by the tile loops above.
        }
        let count = plans.len();
        graph.queries.fetch_add(count as u64, Ordering::Relaxed);
        // Every plan kind routes through exactly one of the loops above;
        // a hole is a router bug, answered as a 500, not an abort.
        let mut rows = Vec::with_capacity(results.len());
        for r in results {
            match r {
                Some(v) => rows.push(v),
                None => return Response::error(500, "internal error: batch slot left unfilled"),
            }
        }
        Response::json(
            200,
            Json::obj(vec![
                ("id", Json::Str(graph.id.clone())),
                ("count", Json::Num(count as f64)),
                ("results", Json::Arr(rows)),
                ("ms", Json::Num(sw.ms())),
            ])
            .render(),
        )
    }
}

/// Map an admission rejection onto its HTTP reply: `429` for rate
/// limiting, `503` for shed/queue-full/shutdown, `504` for a deadline
/// that ran out while parked. Every rejection carries a `Retry-After`
/// header (integer seconds, priced from the bucket refill for rate
/// limits) and a JSON body naming the machine-readable `reason`.
fn reject_response(r: Reject) -> Response {
    let status = match r {
        Reject::RateLimited { .. } => 429,
        Reject::DeadlineExceeded => 504,
        Reject::Shed | Reject::QueueFull | Reject::ShuttingDown => 503,
    };
    let detail = match r {
        Reject::RateLimited { .. } => "tenant rate limit exceeded",
        Reject::Shed => "shedding expensive queries under load",
        Reject::QueueFull => "admission queue full",
        Reject::DeadlineExceeded => "deadline exceeded while queued for admission",
        Reject::ShuttingDown => "server shutting down",
    };
    let retry = r.retry_after();
    Response::json(
        status,
        Json::obj(vec![
            ("error", Json::Str(detail.into())),
            ("reason", Json::Str(r.reason().into())),
            ("retry_after_s", Json::Num(retry as f64)),
        ])
        .render(),
    )
    .with_header("retry-after", retry.to_string())
}

/// `504 deadline exceeded` reply for expiries observed after admission
/// (at dequeue, pre-dispatch, or a kernel checkpoint).
fn deadline_response(detail: &str) -> Response {
    Response::json(
        504,
        Json::obj(vec![
            ("error", Json::Str(detail.into())),
            ("reason", Json::Str("deadline".into())),
        ])
        .render(),
    )
}

/// Prefix a per-query result object with its query name (batch rows
/// are self-describing).
fn with_query_name(name: &str, j: Json) -> Json {
    let mut pairs = j.into_obj_pairs();
    pairs.insert(0, ("query".to_string(), Json::Str(name.to_string())));
    Json::Obj(pairs)
}

/// Parse an SpMV/SSSP request body into its coalescable form,
/// validating ranges against the prepared graph.
fn parse_coalescable(g: &PreparedGraph, ep: Endpoint, body: &Json) -> anyhow::Result<BatchQuery> {
    match ep {
        Endpoint::Spmv => Ok(BatchQuery::Spmv { seed: body.get("seed").and_then(Json::as_u64) }),
        Endpoint::Sssp => {
            let source = match body.get("source").and_then(Json::as_u64) {
                Some(s) => {
                    anyhow::ensure!((s as usize) < g.csr.n(), "source {s} out of range");
                    s as u32
                }
                None => g.default_source(),
            };
            Ok(BatchQuery::Sssp { source })
        }
        _ => anyhow::bail!("not a coalescable query"),
    }
}

/// Render one coalesced answer (the per-query response fields plus the
/// realized batch width).
fn coalesced_json(q: BatchQuery, out: BatchOut, width: usize) -> Json {
    match (q, out) {
        (BatchQuery::Spmv { seed }, BatchOut::Spmv { digest }) => {
            let mut pairs = vec![("digest", Json::Num(digest))];
            if let Some(s) = seed {
                pairs.push(("seed", Json::Num(s as f64)));
            }
            pairs.push(("batch_width", Json::Num(width as f64)));
            Json::obj(pairs)
        }
        (BatchQuery::Sssp { source }, BatchOut::Sssp { digest, reached }) => Json::obj(vec![
            ("digest", Json::Num(digest)),
            ("source", Json::Num(source as f64)),
            ("reached", Json::Num(reached as f64)),
            ("batch_width", Json::Num(width as f64)),
        ]),
        // lint: allow(panic-path): structurally dead — every answer is
        // produced from the very BatchQuery that keys it (tile loops
        // and coalescer groups are homogeneous by construction), so no
        // request data can reach this arm.
        _ => unreachable!("kind mismatch between query and answer"),
    }
}

/// Execute one non-coalescable query against a prepared artifact.
/// Digests mirror `pipeline::Pipeline::run_app` so served results can
/// be validated against the offline pipeline. PageRank runs the
/// deterministic parallel kernel — bit-identical to the sequential one
/// at every thread count, so responses stay reproducible under any
/// server parallelism.
fn run_query(g: &PreparedGraph, ep: Endpoint, body: &Json) -> anyhow::Result<Json> {
    let csr = &*g.csr;
    match ep {
        Endpoint::Pagerank => {
            let iters = body.get("iters").and_then(Json::as_u64).unwrap_or(20) as usize;
            anyhow::ensure!(iters >= 1 && iters <= 10_000, "iters must be in 1..=10000");
            let p = pagerank::PrParams { max_iters: iters, ..Default::default() };
            // Reuse the transpose cached at prepare time instead of
            // rebuilding it per query (same stable in-neighbor order,
            // so answers stay bit-identical to the wrapper).
            let r = crate::obs::span("kernel.pagerank", || {
                pagerank::pagerank_parallel_pull(csr, &g.transpose, p)
            });
            let digest: f64 = r.ranks.iter().map(|&v| v as f64).sum();
            Ok(Json::obj(vec![
                ("digest", Json::Num(digest)),
                ("iters", Json::Num(r.iters as f64)),
            ]))
        }
        Endpoint::Tc => {
            let view = g.tc_view();
            let triangles =
                crate::obs::span("kernel.tc", || tc::triangle_count_ranked(&view.dag, &view.rank));
            Ok(Json::obj(vec![
                ("digest", Json::Num(triangles as f64)),
                ("triangles", Json::Num(triangles as f64)),
            ]))
        }
        _ => anyhow::bail!("not a query endpoint"),
    }
}

/// Upper bound on ops per `POST /mutate` batch (one WAL record each).
pub const MAX_MUTATE_OPS: usize = 1 << 16;

/// Parse and validate a `POST /mutate` body into WAL ops (original
/// label space, ids checked against `n` before any byte is written).
fn parse_ops(body: &Json, n: usize) -> anyhow::Result<Vec<WalOp>> {
    let entries = match body.get("ops") {
        Some(Json::Arr(items)) if !items.is_empty() => items,
        Some(Json::Arr(_)) => anyhow::bail!("ops array is empty"),
        _ => anyhow::bail!("body must carry {{\"ops\": [...]}}"),
    };
    anyhow::ensure!(
        entries.len() <= MAX_MUTATE_OPS,
        "{} ops exceed the {MAX_MUTATE_OPS} per-batch cap",
        entries.len()
    );
    let mut ops = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let kind = match e.get("op").and_then(Json::as_str) {
            Some("upsert") => OP_UPSERT,
            Some("delete") => OP_DELETE,
            Some(other) => anyhow::bail!("ops[{i}]: unknown op {other:?} (upsert|delete)"),
            None => anyhow::bail!("ops[{i}] missing \"op\" (upsert|delete)"),
        };
        let vertex = |name: &str| -> anyhow::Result<u32> {
            let v = e
                .get(name)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("ops[{i}] missing vertex {name:?}"))?;
            anyhow::ensure!((v as usize) < n, "ops[{i}]: {name}={v} out of range (n={n})");
            Ok(v as u32)
        };
        let (u, v) = (vertex("u")?, vertex("v")?);
        let w = e.get("w").and_then(|j| j.as_f64()).unwrap_or(1.0) as f32;
        anyhow::ensure!(w.is_finite(), "ops[{i}]: weight must be finite");
        ops.push(WalOp { kind, u, v, w });
    }
    Ok(ops)
}

/// Execute one query against a live (base ⊕ delta) snapshot via the
/// merged kernels in [`crate::graph::delta`]. Answer shapes mirror the
/// frozen path exactly (same digests for the same logical graph), plus
/// a `delta_entries` field as evidence the overlay was consulted.
fn run_merged_query(
    g: &PreparedGraph,
    d: &DeltaOverlay,
    ep: Endpoint,
    body: &Json,
) -> anyhow::Result<Json> {
    use crate::graph::delta;
    let entries = ("delta_entries", Json::Num(d.len() as f64));
    match ep {
        Endpoint::Spmv => {
            let seed = body.get("seed").and_then(Json::as_u64);
            let x = coalesce::rhs_vector(g.csr.n(), seed);
            let y = crate::obs::span("kernel.spmv_merged", || {
                delta::spmv_merged_parallel(&g.csr, d, &x)
            });
            let digest: f64 = y.iter().map(|&v| v as f64).sum();
            let mut pairs = vec![("digest", Json::Num(digest))];
            if let Some(s) = seed {
                pairs.push(("seed", Json::Num(s as f64)));
            }
            pairs.push(entries);
            Ok(Json::obj(pairs))
        }
        Endpoint::Sssp => {
            let source = match body.get("source").and_then(Json::as_u64) {
                Some(s) => {
                    anyhow::ensure!((s as usize) < g.csr.n(), "source {s} out of range");
                    s as u32
                }
                None => g.default_source(),
            };
            let dist = crate::obs::span("kernel.sssp_merged", || {
                delta::sssp_merged_parallel(&g.csr, d, source)
            });
            let digest: f64 = dist.iter().filter(|v| v.is_finite()).map(|&v| v as f64).sum();
            let reached = dist.iter().filter(|v| v.is_finite()).count();
            Ok(Json::obj(vec![
                ("digest", Json::Num(digest)),
                ("source", Json::Num(source as f64)),
                ("reached", Json::Num(reached as f64)),
                entries,
            ]))
        }
        Endpoint::Pagerank => {
            let iters = body.get("iters").and_then(Json::as_u64).unwrap_or(20) as usize;
            anyhow::ensure!(iters >= 1 && iters <= 10_000, "iters must be in 1..=10000");
            let p = pagerank::PrParams { max_iters: iters, ..Default::default() };
            let r = crate::obs::span("kernel.pagerank_merged", || {
                delta::pagerank_merged_parallel(&g.csr, &g.transpose, d, p)
            });
            let digest: f64 = r.ranks.iter().map(|&v| v as f64).sum();
            Ok(Json::obj(vec![
                ("digest", Json::Num(digest)),
                ("iters", Json::Num(r.iters as f64)),
                entries,
            ]))
        }
        Endpoint::Tc => {
            // No incremental TC kernel: materialize the merged COO and
            // run the same symmetrize → orient pipeline the frozen
            // tc_view uses. Correctness over speed while the overlay is
            // hot — compaction folds it and restores the cached view.
            use crate::convert;
            let merged = delta::merged_coo(&g.csr, d);
            let und = merged.symmetrized().deduped();
            let sorted = convert::sort_coo_by_src(&und);
            let csr = convert::coo_to_csr_parallel(&sorted);
            let rank = tc::degree_rank(&csr);
            let dag = tc::orient_by_rank(&csr, &rank);
            let triangles =
                crate::obs::span("kernel.tc_merged", || tc::triangle_count_ranked(&dag, &rank));
            Ok(Json::obj(vec![
                ("digest", Json::Num(triangles as f64)),
                ("triangles", Json::Num(triangles as f64)),
                entries,
            ]))
        }
        _ => anyhow::bail!("not a query endpoint"),
    }
}

const USAGE: &str = "boba graph-analytics service\n\
  GET  /healthz                      liveness only\n\
  GET  /readyz                       503 while preparing or shedding\n\
  GET  /stats[?format=text]\n\
  GET  /metrics                      Prometheus text exposition\n\
  GET  /debug/traces[?n=K]           recent stage-span traces, newest first\n\
  GET  /debug/faults                 armed fault-injection points\n\
  POST /debug/faults                 {\"spec\": \"prepare-fail:1\"} (\"\" disarms)\n\
  GET  /graphs\n\
  POST /graphs                       {\"dataset\": \"rmat:16:16\", \"scheme\": \"boba\"}\n\
  POST /graphs/{id}/spmv             {\"seed\": 7}        (optional seeded RHS)\n\
  POST /graphs/{id}/pagerank         {\"iters\": 20}\n\
  POST /graphs/{id}/sssp             {\"source\": 0}\n\
  POST /graphs/{id}/tc\n\
  POST /graphs/{id}/mutate           {\"ops\": [{\"op\": \"upsert\", \"u\": 1, \"v\": 2, \"w\": 0.5},\n\
                                              {\"op\": \"delete\", \"u\": 3, \"v\": 4}]}\n\
                                     (needs --wal-dir; acked after fsync)\n\
  POST /graphs/{id}/compact          fold the delta into a fresh BOBA epoch now\n\
  GET  /graphs/{id}/digest           label-invariant graph digest (crash evidence)\n\
  POST /query/batch                  {\"id\": \"rmat:16:16@boba\",\n\
                                      \"queries\": [{\"query\": \"spmv\"},\n\
                                                  {\"query\": \"sssp\", \"source\": 3}]}\n\
  Headers: x-tenant (rate-limit bucket), x-deadline-ms (request deadline)\n";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::admission::AdmissionConfig;
    use crate::server::coalesce::CoalesceConfig;
    use crate::server::registry::RegistryConfig;

    fn router() -> Router {
        router_with(None, AdmissionConfig::default())
    }

    fn router_with_format(format: Option<&str>) -> Router {
        router_with(format, AdmissionConfig::default())
    }

    fn router_with(format: Option<&str>, adm: AdmissionConfig) -> Router {
        Router::new(
            Arc::new(GraphRegistry::new(RegistryConfig {
                capacity: 4,
                batch: 1000,
                in_flight: 2,
                seed: 5,
                format: format.map(|s| s.to_string()),
                ..RegistryConfig::default()
            })),
            Arc::new(ServerStats::new()),
            Arc::new(Coalescer::new(CoalesceConfig::default())),
            Arc::new(Admission::new(adm)),
        )
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            query: String::new(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn req_with_headers(
        method: &str,
        path: &str,
        body: &str,
        headers: &[(&str, &str)],
    ) -> Request {
        let mut r = req(method, path, body);
        r.headers =
            headers.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        r
    }

    fn json_of(resp: &Response) -> Json {
        Json::parse(&String::from_utf8_lossy(&resp.body)).unwrap()
    }

    #[test]
    fn health_and_usage() {
        let r = router();
        let resp = r.handle(&req("GET", "/healthz", ""));
        assert_eq!(resp.status, 200);
        assert_eq!(json_of(&resp).get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(r.handle(&req("GET", "/", "")).status, 200);
    }

    #[test]
    fn ingest_then_query_roundtrip() {
        let r = router();
        let resp = r.handle(&req(
            "POST",
            "/graphs",
            "{\"dataset\": \"pa:1500:4\", \"scheme\": \"boba\"}",
        ));
        assert_eq!(resp.status, 201, "{}", String::from_utf8_lossy(&resp.body));
        let id = json_of(&resp)
            .get("id")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        assert_eq!(id, "pa:1500:4@boba");

        // Re-ingest is a cache hit.
        let resp2 = r.handle(&req(
            "POST",
            "/graphs",
            "{\"dataset\": \"pa:1500:4\", \"scheme\": \"boba\"}",
        ));
        assert_eq!(resp2.status, 200);
        assert_eq!(json_of(&resp2).get("cached").unwrap().as_bool(), Some(true));

        // SpMV digest over ones = m for an unweighted graph.
        let q = r.handle(&req("POST", &format!("/graphs/{id}/spmv"), ""));
        assert_eq!(q.status, 200);
        let body = json_of(&q);
        let m = json_of(&resp).get("m").unwrap().as_f64().unwrap();
        assert!((body.get("digest").unwrap().as_f64().unwrap() - m).abs() < 1e-6 * m);

        // PageRank digest ~ 1.
        let q = r.handle(&req(
            "POST",
            &format!("/graphs/{id}/pagerank"),
            "{\"iters\": 30}",
        ));
        assert_eq!(q.status, 200);
        let d = json_of(&q).get("digest").unwrap().as_f64().unwrap();
        assert!((d - 1.0).abs() < 0.05, "pagerank digest {d}");

        // SSSP + TC respond.
        assert_eq!(
            r.handle(&req("POST", &format!("/graphs/{id}/sssp"), "")).status,
            200
        );
        assert_eq!(
            r.handle(&req("POST", &format!("/graphs/{id}/tc"), "")).status,
            200
        );

        // Stats saw the traffic.
        let stats = json_of(&r.handle(&req("GET", "/stats", "")));
        let eps = stats.get("endpoints").unwrap();
        assert_eq!(eps.get("ingest").unwrap().get("count").unwrap().as_u64(), Some(2));
        assert_eq!(eps.get("spmv").unwrap().get("count").unwrap().as_u64(), Some(1));
        assert!(stats.get("registry").unwrap().get("hits").unwrap().as_u64().unwrap() >= 1);

        // Listing shows the artifact with a query count.
        let listing = json_of(&r.handle(&req("GET", "/graphs", "")));
        match listing {
            Json::Arr(items) => {
                assert_eq!(items.len(), 1);
                assert!(items[0].get("queries").unwrap().as_u64().unwrap() >= 4);
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn errors_are_structured() {
        let r = router();
        assert_eq!(r.handle(&req("POST", "/graphs", "{not json")).status, 400);
        assert_eq!(r.handle(&req("POST", "/graphs", "{}")).status, 422);
        assert_eq!(
            r.handle(&req("POST", "/graphs/zzz@boba/spmv", "")).status,
            404
        );
        assert_eq!(r.handle(&req("DELETE", "/graphs", "")).status, 405);
        assert_eq!(r.handle(&req("GET", "/nope", "")).status, 404);
        let bad_query = r.handle(&req("POST", "/graphs/x@y/frobnicate", ""));
        assert_eq!(bad_query.status, 404);
    }

    #[test]
    fn batch_endpoint_runs_heterogeneous_queries_in_order() {
        let r = router();
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1500:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let body = format!(
            "{{\"id\": \"{id}\", \"queries\": [\
             {{\"query\": \"spmv\"}},\
             {{\"query\": \"sssp\"}},\
             {{\"query\": \"pagerank\", \"iters\": 10}},\
             {{\"query\": \"spmv\", \"seed\": 7}},\
             {{\"query\": \"pagerank\", \"iters\": 10}}]}}"
        );
        let resp = r.handle(&req("POST", "/query/batch", &body));
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let out = json_of(&resp);
        assert_eq!(out.get("count").unwrap().as_u64(), Some(5));
        let rows = match out.get("results").unwrap() {
            Json::Arr(items) => items.clone(),
            other => panic!("results not an array: {other:?}"),
        };
        assert_eq!(rows.len(), 5);
        // Input order preserved, names attached.
        for (i, want) in ["spmv", "sssp", "pagerank", "spmv", "pagerank"].iter().enumerate() {
            assert_eq!(rows[i].get("query").unwrap().as_str(), Some(*want), "row {i}");
        }
        // The two spmv entries rode one tile (width 2); the plain one
        // answers exactly like the direct endpoint.
        assert_eq!(rows[0].get("batch_width").unwrap().as_u64(), Some(2));
        let direct = json_of(&r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "")));
        assert_eq!(
            rows[0].get("digest").unwrap().as_f64(),
            direct.get("digest").unwrap().as_f64(),
            "batched spmv must answer exactly like the direct endpoint"
        );
        // Identical pagerank entries dedup to one execution but both rows
        // answer.
        assert_eq!(
            rows[2].get("digest").unwrap().as_f64(),
            rows[4].get("digest").unwrap().as_f64()
        );
        // Width histogram saw the tile.
        let stats = json_of(&r.handle(&req("GET", "/stats", "")));
        let co = stats.get("coalescer").unwrap();
        assert_eq!(co.get("spmv").unwrap().get("queries").unwrap().as_u64(), Some(3));
        assert!(co.get("spmv").unwrap().get("widths").unwrap().get("2").is_some());
    }

    #[test]
    fn batch_endpoint_validates_inputs() {
        let r = router();
        assert_eq!(r.handle(&req("POST", "/query/batch", "{not json")).status, 400);
        assert_eq!(r.handle(&req("POST", "/query/batch", "{}")).status, 422);
        assert_eq!(
            r.handle(&req("POST", "/query/batch", "{\"id\": \"nope@x\", \"queries\": [{\"query\": \"spmv\"}]}"))
                .status,
            404
        );
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:900:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        assert_eq!(
            r.handle(&req("POST", "/query/batch", &format!("{{\"id\": \"{id}\", \"queries\": []}}")))
                .status,
            422
        );
        assert_eq!(
            r.handle(&req(
                "POST",
                "/query/batch",
                &format!("{{\"id\": \"{id}\", \"queries\": [{{\"query\": \"frobnicate\"}}]}}")
            ))
            .status,
            422
        );
        assert_eq!(
            r.handle(&req(
                "POST",
                "/query/batch",
                &format!(
                    "{{\"id\": \"{id}\", \"queries\": [{{\"query\": \"sssp\", \"source\": 99999999}}]}}"
                )
            ))
            .status,
            422
        );
        // A doomed batch is rejected at plan time: the invalid pagerank
        // entry 422s before the spmv tile runs, so no kernel pass is
        // wasted and the width histogram stays untouched.
        let before = r.coalescer.spmv_widths().batches();
        let resp = r.handle(&req(
            "POST",
            "/query/batch",
            &format!(
                "{{\"id\": \"{id}\", \"queries\": [{{\"query\": \"spmv\"}}, \
                 {{\"query\": \"pagerank\", \"iters\": 0}}]}}"
            ),
        ));
        assert_eq!(resp.status, 422);
        assert_eq!(
            r.coalescer.spmv_widths().batches(),
            before,
            "no tile may execute for a batch that fails validation"
        );
        assert_eq!(r.handle(&req("GET", "/query/batch", "")).status, 405);
    }

    #[test]
    fn seeded_spmv_digest_differs_from_ones() {
        let r = router();
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1200:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let ones = json_of(&r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "")));
        let seeded =
            json_of(&r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "{\"seed\": 11}")));
        assert_eq!(seeded.get("seed").unwrap().as_u64(), Some(11));
        assert_ne!(
            ones.get("digest").unwrap().as_f64(),
            seeded.get("digest").unwrap().as_f64(),
            "a seeded RHS must be a genuinely different query"
        );
        assert!(ones.get("batch_width").unwrap().as_u64().unwrap() >= 1);
    }

    #[test]
    fn metrics_exposition_is_strictly_parseable() {
        let r = router();
        r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1000:4\"}"));
        let q = r.handle(&req("POST", "/graphs/pa:1000:4@boba/spmv", ""));
        assert_eq!(q.status, 200);
        let resp = r.handle(&req("GET", "/metrics", ""));
        assert_eq!(resp.status, 200);
        assert!(resp.content_type.starts_with("text/plain"), "{}", resp.content_type);
        let text = String::from_utf8(resp.body.clone()).unwrap();
        // The scrape parser rejects headerless samples, orphan TYPE
        // lines, and duplicate families — parsing succeeding IS the
        // conformance check.
        let scrape = crate::obs::text::Scrape::parse(&text).expect("conformant exposition");
        assert!(scrape.families.len() >= 10, "only {} families", scrape.families.len());
        for fam in [
            "boba_uptime_seconds",
            "boba_requests_total",
            "boba_request_errors_total",
            "boba_request_duration_seconds",
            "boba_registry_graphs",
            "boba_registry_hits_total",
            "boba_registry_prepares_total",
            "boba_format_bytes_per_edge",
            "boba_pool_dispatches_total",
            "boba_coalesce_batches_total",
            "boba_coalesce_batch_width",
            "boba_stage_duration_seconds",
            "boba_process_resident_memory_bytes",
            "boba_traces_total",
            "boba_mutations_total",
            "boba_compactions_total",
            "boba_io_corruption_total",
            "boba_delta_entries",
            "boba_recovering",
        ] {
            assert!(scrape.family(fam).is_some(), "missing family {fam}");
        }
        // Corruption counters pre-register every kind at zero.
        for kind in crate::obs::corrupt::KINDS {
            assert!(
                scrape.value("boba_io_corruption_total", &[("kind", kind)]).is_some(),
                "missing corruption kind {kind}"
            );
        }
        assert!(scrape.value("boba_requests_total", &[("endpoint", "ingest")]).unwrap() >= 1.0);
        let hist = scrape.histogram("boba_request_duration_seconds", &[("endpoint", "spmv")]);
        assert_eq!(hist.last().map(|b| b.0), Some(f64::INFINITY), "buckets end in +Inf");
        assert!(hist.last().unwrap().1 >= 1.0, "the spmv request landed in the histogram");
        // Batch-width buckets are the explicit 1..=MAX_RHS ladder.
        let widths = scrape.histogram("boba_coalesce_batch_width", &[("kind", "spmv")]);
        assert!(widths.last().unwrap().1 >= 1.0, "one single-query pass recorded");
        // Prepare stages surfaced with per-stage labels.
        let stages = scrape.family("boba_stage_duration_seconds").unwrap();
        assert!(
            stages.samples.iter().any(|s| s.label("stage") == Some("prepare.reorder")),
            "cold prepare must record its reorder stage"
        );
    }

    fn router_with_wal(tag: &str) -> (Router, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("boba-router-wal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = Router::new(
            Arc::new(GraphRegistry::new(RegistryConfig {
                capacity: 4,
                batch: 1000,
                in_flight: 2,
                seed: 5,
                wal_dir: Some(dir.clone()),
                compact_threshold: 0, // manual /compact only
                ..RegistryConfig::default()
            })),
            Arc::new(ServerStats::new()),
            Arc::new(Coalescer::new(CoalesceConfig::default())),
            Arc::new(Admission::new(AdmissionConfig::default())),
        );
        (r, dir)
    }

    #[test]
    fn mutate_without_wal_dir_is_a_clean_503() {
        let r = router();
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1000:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let m = r.handle(&req(
            "POST",
            &format!("/graphs/{id}/mutate"),
            "{\"ops\": [{\"op\": \"upsert\", \"u\": 0, \"v\": 1}]}",
        ));
        assert_eq!(m.status, 503, "{}", String::from_utf8_lossy(&m.body));
        assert!(String::from_utf8_lossy(&m.body).contains("--wal-dir"));
        // The digest page still serves a base-only digest.
        assert_eq!(r.handle(&req("GET", &format!("/graphs/{id}/digest"), "")).status, 200);
    }

    #[test]
    fn mutate_compact_digest_roundtrip() {
        let (r, dir) = router_with_wal("roundtrip");
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1500:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let m0 = json_of(&r.handle(&req("GET", &format!("/graphs/{id}/digest"), "")));
        let frozen = m0.get("digest").unwrap().as_str().unwrap().to_string();

        // Validation failures happen before any byte is written.
        for bad in [
            "{}",
            "{\"ops\": []}",
            "{\"ops\": [{\"op\": \"frob\", \"u\": 0, \"v\": 1}]}",
            "{\"ops\": [{\"op\": \"upsert\", \"u\": 999999, \"v\": 1}]}",
        ] {
            let resp = r.handle(&req("POST", &format!("/graphs/{id}/mutate"), bad));
            assert_eq!(resp.status, 422, "{bad} -> {}", String::from_utf8_lossy(&resp.body));
        }

        // Durable upserts + a delete; the ack carries the WAL seq.
        let m = r.handle(&req(
            "POST",
            &format!("/graphs/{id}/mutate"),
            "{\"ops\": [{\"op\": \"upsert\", \"u\": 1, \"v\": 2, \"w\": 2.5},\
                        {\"op\": \"upsert\", \"u\": 3, \"v\": 4},\
                        {\"op\": \"delete\", \"u\": 0, \"v\": 1}]}",
        ));
        assert_eq!(m.status, 200, "{}", String::from_utf8_lossy(&m.body));
        let ack = json_of(&m);
        assert_eq!(ack.get("durable").unwrap().as_bool(), Some(true));
        assert_eq!(ack.get("ops").unwrap().as_u64(), Some(3));
        assert!(ack.get("delta_entries").unwrap().as_u64().unwrap() >= 1);

        // Merged queries answer and carry the overlay marker.
        let q = json_of(&r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "")));
        assert!(q.get("delta_entries").unwrap().as_u64().unwrap() >= 1);
        let pr = r.handle(&req("POST", &format!("/graphs/{id}/pagerank"), "{\"iters\": 5}"));
        assert_eq!(pr.status, 200, "{}", String::from_utf8_lossy(&pr.body));
        let tc = r.handle(&req("POST", &format!("/graphs/{id}/tc"), ""));
        assert_eq!(tc.status, 200, "{}", String::from_utf8_lossy(&tc.body));
        // Batch path uses the same merged snapshot.
        let b = r.handle(&req(
            "POST",
            "/query/batch",
            &format!("{{\"id\": \"{id}\", \"queries\": [{{\"query\": \"spmv\"}}, {{\"query\": \"sssp\"}}]}}"),
        ));
        assert_eq!(b.status, 200, "{}", String::from_utf8_lossy(&b.body));

        // The mutated digest differs from frozen, survives compaction,
        // and the epoch advances.
        let live = json_of(&r.handle(&req("GET", &format!("/graphs/{id}/digest"), "")));
        let mutated = live.get("digest").unwrap().as_str().unwrap().to_string();
        assert_ne!(mutated, frozen, "mutations must change the digest");
        let c = r.handle(&req("POST", &format!("/graphs/{id}/compact"), ""));
        assert_eq!(c.status, 200, "{}", String::from_utf8_lossy(&c.body));
        let cj = json_of(&c);
        assert_eq!(cj.get("compacted").unwrap().as_bool(), Some(true));
        assert_eq!(cj.get("delta_entries").unwrap().as_u64(), Some(0));
        let after = json_of(&r.handle(&req("GET", &format!("/graphs/{id}/digest"), "")));
        assert_eq!(after.get("digest").unwrap().as_str().unwrap(), mutated);
        assert!(after.get("epoch").unwrap().as_u64().unwrap() >= 1);

        // Post-compaction the overlay is empty: queries take the frozen
        // path again (no delta_entries marker) on the new epoch.
        let q2 = json_of(&r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "")));
        assert!(q2.get("delta_entries").is_none());

        // Mutation traffic shows up in /metrics.
        let text =
            String::from_utf8(r.handle(&req("GET", "/metrics", "")).body.clone()).unwrap();
        let scrape = crate::obs::text::Scrape::parse(&text).unwrap();
        assert!(scrape.value("boba_mutations_total", &[]).unwrap() >= 3.0);
        assert!(scrape.value("boba_compactions_total", &[]).unwrap() >= 1.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn format_bytes_per_edge_gauge_tracks_artifacts() {
        let r = router_with_format(Some("delta"));
        r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1200:4\"}"));
        let resp = r.handle(&req("GET", "/metrics", ""));
        let text = String::from_utf8(resp.body.clone()).unwrap();
        let scrape = crate::obs::text::Scrape::parse(&text).expect("conformant exposition");
        let bpe = scrape
            .value(
                "boba_format_bytes_per_edge",
                &[("graph", "pa:1200:4@boba"), ("format", "delta")],
            )
            .expect("format-bearing artifact must publish a gauge sample");
        assert!(bpe > 0.0 && bpe <= 4.0 + 1e-12, "got {bpe}");
    }

    #[test]
    fn traces_are_recorded_and_served() {
        let r = router();
        // Tracing can be momentarily off while the obs kill-switch test
        // (same process) holds the global flag down; retry until one of
        // our requests is traced end to end.
        let mut rid = None;
        for _ in 0..50 {
            crate::obs::set_enabled(true);
            let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:1000:4\"}"));
            assert!(resp.status == 200 || resp.status == 201);
            if let Some((_, v)) = resp.extra.iter().find(|(k, _)| k == "x-request-id") {
                rid = Some(v.clone());
                break;
            }
        }
        let rid = rid.expect("a traced request should land");
        assert!(rid.starts_with("r-"), "{rid}");
        let mut tr = req("GET", "/debug/traces", "");
        tr.query = "n=64".to_string();
        let resp = r.handle(&tr);
        assert_eq!(resp.status, 200);
        let body = json_of(&resp);
        assert_eq!(body.get("capacity").unwrap().as_u64(), Some(256));
        let rows = match body.get("traces").unwrap() {
            Json::Arr(items) => items.clone(),
            other => panic!("traces not an array: {other:?}"),
        };
        // The ring is process-global (other tests push too): find ours.
        let ours = rows
            .iter()
            .find(|t| t.get("id").and_then(Json::as_str) == Some(&rid))
            .expect("our trace is in the ring");
        assert_eq!(ours.get("endpoint").unwrap().as_str(), Some("ingest"));
        // Cold prepare answers 201; if the first loop iteration raced
        // the kill-switch test, the traced one was a 200 cache hit.
        let status = ours.get("status").unwrap().as_u64().unwrap();
        assert!(status == 200 || status == 201, "status {status}");
        // Introspection responses still carry request ids even though
        // they stay out of the ring.
        let m = r.handle(&req("GET", "/metrics", ""));
        assert!(
            m.extra.iter().any(|(k, _)| k == "x-request-id")
                || !crate::obs::enabled(),
            "metrics responses echo a request id"
        );
    }

    #[test]
    fn sssp_validates_source() {
        let r = router();
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:800:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let bad = r.handle(&req(
            "POST",
            &format!("/graphs/{id}/sssp"),
            "{\"source\": 99999999}",
        ));
        assert_eq!(bad.status, 422);
    }

    #[test]
    fn rate_limit_answers_429_with_retry_after() {
        let r = router_with(None, AdmissionConfig { rate: 0.001, burst: 1.0, max_inflight: 0 });
        let ok = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:600:4\"}"));
        assert_eq!(ok.status, 201, "{}", String::from_utf8_lossy(&ok.body));
        let rej = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:600:4\"}"));
        assert_eq!(rej.status, 429);
        let body = json_of(&rej);
        assert_eq!(body.get("reason").unwrap().as_str(), Some("rate"));
        let (_, ra) = rej
            .extra
            .iter()
            .find(|(k, _)| k == "retry-after")
            .expect("429 carries a Retry-After header");
        assert!(ra.parse::<u64>().unwrap() >= 1, "retry-after was {ra:?}");
        // A different tenant has its own bucket (and hits the cache).
        let other = r.handle(&req_with_headers(
            "POST",
            "/graphs",
            "{\"dataset\": \"pa:600:4\"}",
            &[("x-tenant", "acme")],
        ));
        assert_eq!(other.status, 200);
        // Introspection is never rate limited, and it reports the
        // rejection under (tenant, reason).
        let stats = json_of(&r.handle(&req("GET", "/stats", "")));
        let adm = stats.get("admission").unwrap();
        assert_eq!(adm.get("rejected").unwrap().get("default:rate").unwrap().as_u64(), Some(1));
        let m = r.handle(&req("GET", "/metrics", ""));
        let text = String::from_utf8(m.body.clone()).unwrap();
        let scrape = crate::obs::text::Scrape::parse(&text).expect("conformant exposition");
        assert_eq!(
            scrape.value(
                "boba_admission_rejected_total",
                &[("tenant", "default"), ("reason", "rate")],
            ),
            Some(1.0)
        );
        assert!(scrape.family("boba_inflight").is_some());
        assert!(scrape.family("boba_deadline_exceeded_total").is_some());
    }

    #[test]
    fn spent_deadline_answers_504_without_dispatching() {
        let r = router();
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:700:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let resp = r.handle(&req_with_headers(
            "POST",
            &format!("/graphs/{id}/spmv"),
            "",
            &[("x-deadline-ms", "0")],
        ));
        assert_eq!(resp.status, 504, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(json_of(&resp).get("reason").unwrap().as_str(), Some("deadline"));
        let stats = json_of(&r.handle(&req("GET", "/stats", "")));
        assert!(
            stats.get("admission").unwrap().get("deadline_exceeded").unwrap().as_u64().unwrap()
                >= 1
        );
        // The expired deadline is scoped to its request: the next
        // headerless request on this thread runs unconstrained.
        assert_eq!(r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "")).status, 200);
    }

    #[test]
    fn saturated_gate_sheds_expensive_and_degrades_readyz() {
        let r = router_with(None, AdmissionConfig { rate: 0.0, burst: 0.0, max_inflight: 1 });
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:800:4\"}"));
        assert_eq!(resp.status, 201);
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        assert_eq!(r.handle(&req("GET", "/readyz", "")).status, 200);

        // Hold the single in-flight slot.
        let permit = r.admission.admit("default", false).unwrap();
        let shed = r.handle(&req("POST", &format!("/graphs/{id}/tc"), ""));
        assert_eq!(shed.status, 503);
        assert_eq!(json_of(&shed).get("reason").unwrap().as_str(), Some("shed"));
        let ready = r.handle(&req("GET", "/readyz", ""));
        assert_eq!(ready.status, 503);
        assert!(String::from_utf8_lossy(&ready.body).contains("shedding"));
        // A cheap query with an exhausted budget detaches from the
        // parking queue instead of waiting forever.
        let parked = r.handle(&req_with_headers(
            "POST",
            &format!("/graphs/{id}/spmv"),
            "",
            &[("x-deadline-ms", "0")],
        ));
        assert_eq!(parked.status, 504);

        drop(permit);
        assert_eq!(r.handle(&req("GET", "/readyz", "")).status, 200);
        assert_eq!(r.handle(&req("POST", &format!("/graphs/{id}/spmv"), "")).status, 200);
    }

    #[test]
    fn debug_faults_roundtrip() {
        let _l = crate::obs::chaos::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let r = router();
        // test-point is hooked by nothing, so arming it cannot perturb
        // other tests sharing this process's global fault table.
        let armed = r.handle(&req("POST", "/debug/faults", "{\"spec\": \"test-point:2\"}"));
        assert_eq!(armed.status, 200, "{}", String::from_utf8_lossy(&armed.body));
        assert_eq!(json_of(&armed).get("armed").unwrap().as_bool(), Some(true));
        let got = r.handle(&req("GET", "/debug/faults", ""));
        assert!(String::from_utf8_lossy(&got.body).contains("test-point"));
        // Bad inputs fail loudly without changing the table.
        assert_eq!(r.handle(&req("POST", "/debug/faults", "{\"spec\": \"frobnicate\"}")).status, 422);
        assert_eq!(r.handle(&req("POST", "/debug/faults", "not json")).status, 400);
        assert_eq!(r.handle(&req("POST", "/debug/faults", "{}")).status, 422);
        // The empty spec disarms.
        let off = r.handle(&req("POST", "/debug/faults", "{\"spec\": \"\"}"));
        assert_eq!(json_of(&off).get("armed").unwrap().as_bool(), Some(false));
    }
}
