//! Request routing and query execution: maps the HTTP surface onto the
//! registry and the `algos::` kernels, recording per-endpoint latency.
//!
//! | Route | Effect |
//! |---|---|
//! | `GET  /healthz` | liveness + uptime |
//! | `GET  /stats` | per-endpoint latency histograms + cache counters (`?format=text` for a table) |
//! | `GET  /graphs` | list cached artifacts |
//! | `POST /graphs` | `{"dataset": SPEC, "scheme": NAME}` → prepare (201) or cache hit (200) |
//! | `POST /graphs/{id}/spmv` | one SpMV over the prepared CSR |
//! | `POST /graphs/{id}/pagerank` | PageRank (`{"iters": N}`, default 20) |
//! | `POST /graphs/{id}/sssp` | frontier SSSP (`{"source": V}`, default max-degree vertex) |
//! | `POST /graphs/{id}/tc` | triangle count (lazy oriented view) |
//!
//! Query digests are label-invariant (sums / counts), so the same
//! dataset prepared under different schemes answers identically — the
//! smoke test asserts this against direct `algos::` calls.

use crate::algos::{pagerank, spmv, sssp, tc};
use crate::util::timer::Stopwatch;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::http::{Request, Response};
use super::json::Json;
use super::registry::{GraphRegistry, PreparedGraph};
use super::stats::{Endpoint, ServerStats};

/// The shared request router.
pub struct Router {
    /// Prepared-artifact cache.
    pub registry: Arc<GraphRegistry>,
    /// Latency/error accounting.
    pub stats: Arc<ServerStats>,
}

impl Router {
    /// New router over shared registry and stats.
    pub fn new(registry: Arc<GraphRegistry>, stats: Arc<ServerStats>) -> Router {
        Router { registry, stats }
    }

    /// Handle one request, recording latency under its endpoint slot.
    pub fn handle(&self, req: &Request) -> Response {
        let sw = Stopwatch::start();
        let (endpoint, resp) = self.route(req);
        if let Some(ep) = endpoint {
            self.stats.record(ep, sw.elapsed(), resp.status < 400);
        }
        resp
    }

    fn route(&self, req: &Request) -> (Option<Endpoint>, Response) {
        let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", []) => (None, Response::text(200, USAGE)),
            ("GET", ["healthz"]) => (Some(Endpoint::Healthz), self.healthz()),
            ("GET", ["stats"]) => (Some(Endpoint::Stats), self.stats_page(req)),
            ("GET", ["graphs"]) => (Some(Endpoint::List), self.list()),
            ("POST", ["graphs"]) => (Some(Endpoint::Ingest), self.ingest(req)),
            ("POST", ["graphs", id, query]) => match Endpoint::query_from(query) {
                Some(ep) => (Some(ep), self.query(id, ep, req)),
                None => (
                    None,
                    Response::error(404, &format!("unknown query {query:?} (spmv|pagerank|sssp|tc)")),
                ),
            },
            (_, ["healthz" | "stats" | "graphs", ..]) => {
                (None, Response::error(405, "method not allowed"))
            }
            _ => (None, Response::error(404, "no such route")),
        }
    }

    fn healthz(&self) -> Response {
        Response::json(
            200,
            Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("uptime_ms", Json::Num(self.stats.uptime_ms())),
                ("graphs", Json::Num(self.registry.len() as f64)),
            ])
            .render(),
        )
    }

    fn stats_page(&self, req: &Request) -> Response {
        if req.query.contains("format=text") {
            return Response::text(200, self.stats.render_text());
        }
        let mut body = match self.stats.to_json() {
            Json::Obj(pairs) => pairs,
            _ => unreachable!(),
        };
        body.push(("registry".to_string(), self.registry.stats_json()));
        Response::json(200, Json::Obj(body).render())
    }

    fn list(&self) -> Response {
        let rows: Vec<Json> = self.registry.list().iter().map(|g| g.to_json()).collect();
        Response::json(200, Json::Arr(rows).render())
    }

    fn ingest(&self, req: &Request) -> Response {
        let body = if req.body.is_empty() {
            Json::Obj(Vec::new())
        } else {
            match Json::parse(&req.body_str()) {
                Ok(v) => v,
                Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
            }
        };
        let dataset = match body.get("dataset").and_then(Json::as_str) {
            Some(d) => d.to_string(),
            None => return Response::error(422, "body must carry {\"dataset\": \"...\"}"),
        };
        let scheme = body
            .get("scheme")
            .and_then(Json::as_str)
            .unwrap_or("boba")
            .to_string();
        match self.registry.get_or_prepare(&dataset, &scheme) {
            Ok((g, cached)) => {
                let mut pairs = match g.to_json() {
                    Json::Obj(p) => p,
                    _ => unreachable!(),
                };
                pairs.push(("cached".to_string(), Json::Bool(cached)));
                let status = if cached { 200 } else { 201 };
                Response::json(status, Json::Obj(pairs).render())
            }
            Err(e) => Response::error(422, &format!("{e:#}")),
        }
    }

    fn query(&self, id: &str, ep: Endpoint, req: &Request) -> Response {
        let graph = match self.registry.get(id) {
            Some(g) => g,
            None => {
                return Response::error(
                    404,
                    &format!("no prepared graph {id:?} (POST /graphs first)"),
                )
            }
        };
        let body = if req.body.is_empty() {
            Json::Obj(Vec::new())
        } else {
            match Json::parse(&req.body_str()) {
                Ok(v) => v,
                Err(e) => return Response::error(400, &format!("bad JSON body: {e:#}")),
            }
        };
        let sw = Stopwatch::start();
        let mut pairs = match run_query(&graph, ep, &body) {
            Ok(Json::Obj(p)) => p,
            Ok(_) => unreachable!("queries return objects"),
            Err(e) => return Response::error(422, &format!("{e:#}")),
        };
        graph.queries.fetch_add(1, Ordering::Relaxed);
        pairs.insert(0, ("id".to_string(), Json::Str(graph.id.clone())));
        pairs.insert(1, ("query".to_string(), Json::Str(ep.name().into())));
        pairs.push(("ms".to_string(), Json::Num(sw.ms())));
        Response::json(200, Json::Obj(pairs).render())
    }
}

/// Execute one query against a prepared artifact. Digests mirror
/// `pipeline::Pipeline::run_app` so served results can be validated
/// against the offline pipeline.
fn run_query(g: &PreparedGraph, ep: Endpoint, body: &Json) -> anyhow::Result<Json> {
    let csr = &*g.csr;
    match ep {
        Endpoint::Spmv => {
            let x = vec![1.0f32; csr.n()];
            let y = spmv::spmv_pull(csr, &x);
            let digest: f64 = y.iter().map(|&v| v as f64).sum();
            Ok(Json::obj(vec![("digest", Json::Num(digest))]))
        }
        Endpoint::Pagerank => {
            let iters = body.get("iters").and_then(Json::as_u64).unwrap_or(20) as usize;
            anyhow::ensure!(iters >= 1 && iters <= 10_000, "iters must be in 1..=10000");
            let p = pagerank::PrParams { max_iters: iters, ..Default::default() };
            let r = pagerank::pagerank(csr, p);
            let digest: f64 = r.ranks.iter().map(|&v| v as f64).sum();
            Ok(Json::obj(vec![
                ("digest", Json::Num(digest)),
                ("iters", Json::Num(r.iters as f64)),
            ]))
        }
        Endpoint::Sssp => {
            let source = match body.get("source").and_then(Json::as_u64) {
                Some(s) => {
                    anyhow::ensure!((s as usize) < csr.n(), "source {s} out of range");
                    s as u32
                }
                None => g.default_source(),
            };
            let d = sssp::sssp_frontier(csr, source);
            let reached = d.iter().filter(|v| v.is_finite()).count();
            let digest: f64 = d
                .iter()
                .filter(|v| v.is_finite())
                .map(|&v| v as f64)
                .sum();
            Ok(Json::obj(vec![
                ("digest", Json::Num(digest)),
                ("source", Json::Num(source as f64)),
                ("reached", Json::Num(reached as f64)),
            ]))
        }
        Endpoint::Tc => {
            let view = g.tc_view();
            let triangles = tc::triangle_count_ranked(&view.dag, &view.rank);
            Ok(Json::obj(vec![
                ("digest", Json::Num(triangles as f64)),
                ("triangles", Json::Num(triangles as f64)),
            ]))
        }
        _ => anyhow::bail!("not a query endpoint"),
    }
}

const USAGE: &str = "boba graph-analytics service\n\
  GET  /healthz\n\
  GET  /stats[?format=text]\n\
  GET  /graphs\n\
  POST /graphs                       {\"dataset\": \"rmat:16:16\", \"scheme\": \"boba\"}\n\
  POST /graphs/{id}/spmv\n\
  POST /graphs/{id}/pagerank         {\"iters\": 20}\n\
  POST /graphs/{id}/sssp             {\"source\": 0}\n\
  POST /graphs/{id}/tc\n";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::registry::RegistryConfig;

    fn router() -> Router {
        Router::new(
            Arc::new(GraphRegistry::new(RegistryConfig {
                capacity: 4,
                batch: 1000,
                in_flight: 2,
                seed: 5,
            })),
            Arc::new(ServerStats::new()),
        )
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            query: String::new(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn json_of(resp: &Response) -> Json {
        Json::parse(&String::from_utf8_lossy(&resp.body)).unwrap()
    }

    #[test]
    fn health_and_usage() {
        let r = router();
        let resp = r.handle(&req("GET", "/healthz", ""));
        assert_eq!(resp.status, 200);
        assert_eq!(json_of(&resp).get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(r.handle(&req("GET", "/", "")).status, 200);
    }

    #[test]
    fn ingest_then_query_roundtrip() {
        let r = router();
        let resp = r.handle(&req(
            "POST",
            "/graphs",
            "{\"dataset\": \"pa:1500:4\", \"scheme\": \"boba\"}",
        ));
        assert_eq!(resp.status, 201, "{}", String::from_utf8_lossy(&resp.body));
        let id = json_of(&resp)
            .get("id")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        assert_eq!(id, "pa:1500:4@boba");

        // Re-ingest is a cache hit.
        let resp2 = r.handle(&req(
            "POST",
            "/graphs",
            "{\"dataset\": \"pa:1500:4\", \"scheme\": \"boba\"}",
        ));
        assert_eq!(resp2.status, 200);
        assert_eq!(json_of(&resp2).get("cached").unwrap().as_bool(), Some(true));

        // SpMV digest over ones = m for an unweighted graph.
        let q = r.handle(&req("POST", &format!("/graphs/{id}/spmv"), ""));
        assert_eq!(q.status, 200);
        let body = json_of(&q);
        let m = json_of(&resp).get("m").unwrap().as_f64().unwrap();
        assert!((body.get("digest").unwrap().as_f64().unwrap() - m).abs() < 1e-6 * m);

        // PageRank digest ~ 1.
        let q = r.handle(&req(
            "POST",
            &format!("/graphs/{id}/pagerank"),
            "{\"iters\": 30}",
        ));
        assert_eq!(q.status, 200);
        let d = json_of(&q).get("digest").unwrap().as_f64().unwrap();
        assert!((d - 1.0).abs() < 0.05, "pagerank digest {d}");

        // SSSP + TC respond.
        assert_eq!(
            r.handle(&req("POST", &format!("/graphs/{id}/sssp"), "")).status,
            200
        );
        assert_eq!(
            r.handle(&req("POST", &format!("/graphs/{id}/tc"), "")).status,
            200
        );

        // Stats saw the traffic.
        let stats = json_of(&r.handle(&req("GET", "/stats", "")));
        let eps = stats.get("endpoints").unwrap();
        assert_eq!(eps.get("ingest").unwrap().get("count").unwrap().as_u64(), Some(2));
        assert_eq!(eps.get("spmv").unwrap().get("count").unwrap().as_u64(), Some(1));
        assert!(stats.get("registry").unwrap().get("hits").unwrap().as_u64().unwrap() >= 1);

        // Listing shows the artifact with a query count.
        let listing = json_of(&r.handle(&req("GET", "/graphs", "")));
        match listing {
            Json::Arr(items) => {
                assert_eq!(items.len(), 1);
                assert!(items[0].get("queries").unwrap().as_u64().unwrap() >= 4);
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn errors_are_structured() {
        let r = router();
        assert_eq!(r.handle(&req("POST", "/graphs", "{not json")).status, 400);
        assert_eq!(r.handle(&req("POST", "/graphs", "{}")).status, 422);
        assert_eq!(
            r.handle(&req("POST", "/graphs/zzz@boba/spmv", "")).status,
            404
        );
        assert_eq!(r.handle(&req("DELETE", "/graphs", "")).status, 405);
        assert_eq!(r.handle(&req("GET", "/nope", "")).status, 404);
        let bad_query = r.handle(&req("POST", "/graphs/x@y/frobnicate", ""));
        assert_eq!(bad_query.status, 404);
    }

    #[test]
    fn sssp_validates_source() {
        let r = router();
        let resp = r.handle(&req("POST", "/graphs", "{\"dataset\": \"pa:800:4\"}"));
        let id = json_of(&resp).get("id").unwrap().as_str().unwrap().to_string();
        let bad = r.handle(&req(
            "POST",
            &format!("/graphs/{id}/sssp"),
            "{\"source\": 99999999}",
        ));
        assert_eq!(bad.status, 422);
    }
}
