//! `boba serve` — a std-only graph-analytics service layer.
//!
//! The paper frames BOBA as the cheap front stage of a pragmatic
//! graph-creation pipeline; the ROADMAP's north star is a system that
//! *serves* that pipeline's output under heavy traffic. This module is
//! that service: a multi-threaded HTTP/1.1 server (no dependencies —
//! `std::net` + the same hand-rolled substrate philosophy as
//! [`crate::parallel`]) in front of a [`registry::GraphRegistry`] that
//! runs the Problem-3 pipeline once per `(dataset, scheme)` and serves
//! every subsequent SpMV/PageRank/SSSP/TC query from the cached,
//! reordered CSR. [`loadgen`] is the matching closed-loop client: it
//! turns the paper's end-to-end speedups (§6, up to 3.45×) into a
//! served-queries-per-second number.
//!
//! Architecture: a fixed pool of `workers` threads all block in
//! `accept()` on one shared listener; each accepted connection is
//! served keep-alive until the peer closes, errors, or idles past the
//! read timeout. A worker therefore serves one connection at a time —
//! size the pool to the expected concurrent connection count (the
//! closed-loop loadgen does exactly that). Shutdown sets a flag and
//! wakes every blocked `accept()` with a dummy connection, then joins.

pub mod admission;
pub mod coalesce;
pub mod http;
pub mod live;
pub mod loadgen;
pub mod registry;
pub mod router;
pub mod stats;
pub mod wal;

/// The JSON codec lives in [`crate::util::json`] (it is a substrate, not
/// a server detail); re-exported here so `server::json::Json` paths keep
/// working for the request/response plumbing and its callers.
pub use crate::util::json;

use anyhow::{Context, Result};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use self::admission::{Admission, AdmissionConfig};
use self::coalesce::{CoalesceConfig, Coalescer};
use self::registry::{GraphRegistry, RegistryConfig};
use self::router::Router;
use self::stats::ServerStats;

/// Server configuration (CLI flags map 1:1 onto these fields).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Worker threads == max concurrent connections.
    pub workers: usize,
    /// Prepared-graph LRU capacity.
    pub capacity: usize,
    /// Streaming-ingest batch size (edges).
    pub batch: usize,
    /// Streaming-ingest batches in flight.
    pub in_flight: usize,
    /// Seed for dataset generation/randomization.
    pub seed: u64,
    /// Idle keep-alive timeout per connection.
    pub read_timeout: Duration,
    /// Coalescer window in microseconds (`--batch-window-us`): how long
    /// a batch leader holds the door open for companion SpMV/SSSP
    /// queries. 0 = coalesce only already-queued queries (no added
    /// latency).
    pub batch_window_us: u64,
    /// Maximum coalesced queries per kernel pass (`--max-batch`,
    /// clamped to [`crate::algos::spmm::MAX_RHS`]).
    pub max_batch: usize,
    /// Stage-span tracing (`--no-trace` clears it; the `BOBA_NO_TRACE`
    /// environment variable overrides even `true`).
    pub trace: bool,
    /// Log traces slower than this many milliseconds to stderr as
    /// one-line JSON (`--slow-trace-ms`; `None` = off).
    pub slow_trace_ms: Option<f64>,
    /// Compressed kernel format every prepared artifact carries
    /// (`--format`, a [`crate::runtime::format::FORMAT_NAMES`] name);
    /// `None` serves plain CSR only.
    pub format: Option<String>,
    /// Per-tenant token-bucket refill, tokens/sec (`--rate`; 0 = no
    /// rate limiting).
    pub rate: f64,
    /// Token-bucket capacity (`--burst`; 0 = `max(rate, 1)`).
    pub burst: f64,
    /// Global concurrent-query cap with an equal-size parking queue
    /// behind it (`--max-inflight`; 0 = unlimited).
    pub max_inflight: usize,
    /// Default request deadline in ms applied when the client sends no
    /// `x-deadline-ms` header (`--default-deadline-ms`; `None` = no
    /// default deadline).
    pub default_deadline_ms: Option<u64>,
    /// Durability directory for live mutations (`--wal-dir`). `None`
    /// disables `POST /mutate` entirely (503 with a pointer to the
    /// flag). On restart the directory is scanned and every logged
    /// graph is replayed before `/readyz` goes green.
    pub wal_dir: Option<std::path::PathBuf>,
    /// Overlay size that triggers background compaction — a BOBA re-run
    /// folding the delta into a fresh epoch (`--compact-threshold`;
    /// 0 = manual `POST /graphs/{id}/compact` only).
    pub compact_threshold: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7171".to_string(),
            workers: 8,
            capacity: 8,
            batch: 1 << 16,
            in_flight: 4,
            seed: 42,
            read_timeout: Duration::from_secs(30),
            batch_window_us: 0,
            max_batch: 8,
            trace: true,
            slow_trace_ms: None,
            format: None,
            rate: 0.0,
            burst: 0.0,
            max_inflight: 0,
            default_deadline_ms: None,
            wal_dir: None,
            compact_threshold: 4096,
        }
    }
}

/// A running server: worker threads + shared state. Dropping the handle
/// does *not* stop the server; call [`Server::shutdown`] (tests) or
/// [`Server::join`] (the CLI's run-forever mode).
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Shared artifact cache (exposed for in-process inspection).
    pub registry: Arc<GraphRegistry>,
    /// Shared latency stats.
    pub stats: Arc<ServerStats>,
    /// Shared query coalescer (exposed for in-process inspection).
    pub coalescer: Arc<Coalescer>,
    /// Shared admission state (exposed for in-process inspection).
    pub admission: Arc<Admission>,
}

/// Bind and start serving on a fixed worker pool.
pub fn spawn(cfg: ServerConfig) -> Result<Server> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let registry = Arc::new(GraphRegistry::new(RegistryConfig {
        capacity: cfg.capacity,
        batch: cfg.batch,
        in_flight: cfg.in_flight,
        seed: cfg.seed,
        format: cfg.format.clone(),
        wal_dir: cfg.wal_dir.clone(),
        compact_threshold: cfg.compact_threshold,
    }));
    let stats = Arc::new(ServerStats::new());
    let coalescer = Arc::new(Coalescer::new(CoalesceConfig {
        window: Duration::from_micros(cfg.batch_window_us),
        max_batch: cfg.max_batch,
    }));
    let admission = Arc::new(Admission::new(AdmissionConfig {
        rate: cfg.rate,
        burst: cfg.burst,
        max_inflight: cfg.max_inflight,
    }));
    // Tracing: the config flag gates it, the environment kill switch
    // (BOBA_NO_TRACE) wins over both. Process-global, so an in-process
    // test server shares the flag with everything else.
    if !cfg.trace {
        crate::obs::set_enabled(false);
    }
    crate::obs::init_from_env();
    // Fault injection: armed only when BOBA_FAULTS is set (or a test /
    // the debug endpoint arms it programmatically).
    crate::obs::chaos::init_from_env();
    let mut router =
        Router::new(registry.clone(), stats.clone(), coalescer.clone(), admission.clone());
    router.slow_trace_ms = cfg.slow_trace_ms;
    router.default_deadline_ms = cfg.default_deadline_ms;
    let router = Arc::new(router);
    let shutdown = Arc::new(AtomicBool::new(false));

    // WAL recovery: count the logged graphs *synchronously* so the very
    // first `/readyz` already reports `recovering`, then replay them on
    // a background thread (queries against other graphs keep flowing).
    // The thread honors the shutdown flag between records: killing the
    // server mid-replay exits cleanly without touching undamaged logs.
    if let Some(dir) = cfg.wal_dir.as_deref() {
        let pending = wal::list_metas(dir).map(|m| m.len()).unwrap_or(0);
        registry.set_recovering(pending);
        if pending > 0 {
            let registry = registry.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("boba-recover".to_string())
                .spawn(move || live::recover_all(&registry, &shutdown))
                .context("spawning recovery thread")?;
        }
    }

    let n_workers = cfg.workers.max(1);
    let mut workers = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        let listener = listener.try_clone().context("cloning listener")?;
        let router = router.clone();
        let shutdown = shutdown.clone();
        let read_timeout = cfg.read_timeout;
        workers.push(
            std::thread::Builder::new()
                .name(format!("boba-serve-{w}"))
                .spawn(move || worker_loop(listener, router, shutdown, read_timeout))
                .context("spawning worker")?,
        );
    }
    Ok(Server { addr, shutdown, workers, registry, stats, coalescer, admission })
}

impl Server {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block serving until the process dies (the CLI's `serve` mode).
    pub fn join(self) {
        for h in self.workers {
            h.join().ok();
        }
    }

    /// Graceful shutdown: stop accepting, release coalescer waiters
    /// and admission-parked waiters, wake blocked workers, join.
    /// Connections currently inside a request finish it first (parked
    /// coalesced queries answer with an error); idle keep-alive
    /// connections are abandoned to their read timeout.
    pub fn shutdown(self) {
        // ordering: SeqCst — the shutdown flag; pairs with the worker
        // loops' SeqCst loads so a worker woken by the connect below is
        // guaranteed to observe the flag before its next accept.
        self.shutdown.store(true, Ordering::SeqCst);
        self.coalescer.shutdown();
        self.admission.shutdown();
        for _ in 0..self.workers.len() {
            // Wake one blocked accept() per worker.
            if let Ok(s) = TcpStream::connect(self.addr) {
                drop(s);
            }
        }
        for h in self.workers {
            h.join().ok();
        }
    }
}

fn worker_loop(
    listener: TcpListener,
    router: Arc<Router>,
    shutdown: Arc<AtomicBool>,
    read_timeout: Duration,
) {
    loop {
        // ordering: SeqCst — pairs with `Server::shutdown`'s store (see
        // there); both checks below must see a flag set before the
        // wake-up connect.
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let stream = match listener.accept() {
            Ok((s, _peer)) => s,
            Err(_) => continue,
        };
        // ordering: SeqCst — same pairing; this accept may be the
        // wake-up connection `Server::shutdown` made.
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Errors on one connection never take the worker down.
        let _ = serve_connection(stream, &router, &shutdown, read_timeout);
    }
}

/// Serve one keep-alive connection to completion.
fn serve_connection(
    stream: TcpStream,
    router: &Router,
    shutdown: &AtomicBool,
    read_timeout: Duration,
) -> Result<()> {
    // Fault point: an armed `conn-drop` chaos spec abandons the
    // connection before reading a byte — the client sees a clean TCP
    // close/reset, exactly what a crashed peer or an LB failover looks
    // like, and its retry/timeout handling is what gets tested.
    if crate::obs::chaos::should("conn-drop") {
        return Ok(());
    }
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(read_timeout)).ok();
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    loop {
        // ordering: SeqCst — pairs with `Server::shutdown`'s store;
        // in-flight keep-alive connections stop at a request boundary.
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // peer closed cleanly, or idled out
            Err(e) => {
                // Malformed/oversized input (idle timeouts surface as
                // Ok(None) above): answer 400 best-effort and drop the
                // connection.
                let mut resp = http::Response::error(400, &format!("{e:#}"));
                resp.close = true;
                let _ = resp.write_to(&mut writer);
                let _ = writer.flush();
                return Ok(());
            }
        };
        let close = req.wants_close();
        let mut resp = router.handle(&req);
        if close {
            resp.close = true;
        }
        resp.write_to(&mut writer)?;
        writer.flush()?;
        if resp.close {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::http::HttpClient;
    use super::*;

    fn test_server() -> Server {
        spawn(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 3,
            capacity: 4,
            batch: 2000,
            in_flight: 2,
            seed: 11,
            read_timeout: Duration::from_secs(5),
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn serves_health_and_shuts_down() {
        let server = test_server();
        let addr = server.addr().to_string();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, body) = c.request_json("GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body.get("status").unwrap().as_str(), Some("ok"));
        // Keep-alive: a second request on the same connection.
        let (status, _) = c.request_json("GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        drop(c);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_are_served() {
        let server = test_server();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for _ in 0..3 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = HttpClient::connect(&addr).unwrap();
                for _ in 0..5 {
                    let (status, _) = c.request("GET", "/healthz", b"").unwrap();
                    assert_eq!(status, 200);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(server.stats.total_requests() >= 15);
        server.shutdown();
    }

    #[test]
    fn malformed_request_gets_400_and_close() {
        let server = test_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"NOT A REQUEST\r\n\r\n").unwrap();
        s.flush().unwrap();
        let mut buf = String::new();
        use std::io::Read;
        s.read_to_string(&mut buf).unwrap(); // server closes after 400
        assert!(buf.starts_with("HTTP/1.1 400"), "{buf}");
        server.shutdown();
    }
}
