//! `boba loadgen` — a closed- or open-loop load generator for the
//! service.
//!
//! In the default closed loop each worker owns one persistent
//! connection and issues its next query the moment the previous
//! response lands, so offered load tracks service capacity and the
//! reported number is sustained throughput, not queueing artifacts.
//! With `target_qps` set the workers instead pace an **open-loop**
//! schedule — each sends on its 1/conns share of the target arrival
//! times and never slows down when the server backs up — which is what
//! makes overload measurable: offered load stays above capacity, and
//! the report prices what admission control did about it (`rejected`,
//! `deadline_exceeded`, `retries`, goodput `qps`). Rejected requests
//! (429/503) are retried up to a budget with jittered exponential
//! backoff that honors the server's `Retry-After` pricing. The headline
//! experiment is [`compare`]: the same mixed SpMV/PageRank workload
//! against the same dataset prepared with BOBA vs served with random
//! labels — the paper's end-to-end claim (§6) restated as
//! queries/second.

use crate::util::prng::Xoshiro256;
use crate::util::timer::Stopwatch;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use super::http::HttpClient;
use super::json::Json;

/// Load-generation parameters.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent closed-loop connections (≤ server workers, or
    /// connections will queue behind the pool).
    pub conns: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// Dataset spec to prepare and query.
    pub dataset: String,
    /// Reordering scheme for preparation.
    pub scheme: String,
    /// Weighted query mix, e.g. `[("spmv", 7), ("pagerank", 3)]`.
    pub mix: Vec<(String, u32)>,
    /// PageRank iterations per query.
    pub pr_iters: usize,
    /// PRNG seed for the mix schedule.
    pub seed: u64,
    /// Closed-loop batch mode: send queries through `POST /query/batch`
    /// in explicit batches instead of one-at-a-time endpoint calls, so
    /// every SpMV/SSSP batch is answered by one multi-RHS kernel pass.
    pub coalesce: bool,
    /// Queries per batch request in coalesced mode (ignored otherwise).
    pub batch: usize,
    /// Scrape `GET /metrics` before and after the run and embed the
    /// delta (server-side latency percentiles, prepare-stage breakdown,
    /// realized batch widths) into the report (`--scrape-metrics`).
    pub scrape_metrics: bool,
    /// Open-loop target offered load in queries/sec (`--target-qps`;
    /// 0 = closed loop). Workers send on a fixed arrival schedule and
    /// never wait for a late slot, so offered load holds at the target
    /// even when the server saturates.
    pub target_qps: f64,
    /// Retry budget per request rejected with 429/503 (`--retries`;
    /// 0 = fail fast, the pre-admission behavior).
    pub retries: usize,
    /// Base retry backoff in ms (`--backoff-ms`), doubled per attempt
    /// with ±50% deterministic jitter; the server's `Retry-After`
    /// pricing is used as a floor when it is larger.
    pub backoff_ms: u64,
    /// Fraction of request slots sent as `POST /mutate` batches instead
    /// of queries (`--mutate-frac`; 0 = read-only). Mutated vertices
    /// follow a zipf-like popularity (hubs churn most). Requires the
    /// target server to run with `--wal-dir`; ignored in coalesced mode
    /// (batch requests stay pure queries).
    pub mutate_frac: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7171".to_string(),
            conns: 4,
            requests: 400,
            dataset: "rmat:16:16".to_string(),
            scheme: "boba".to_string(),
            mix: vec![("spmv".to_string(), 7), ("pagerank".to_string(), 3)],
            pr_iters: 5,
            seed: 42,
            coalesce: false,
            batch: 4,
            scrape_metrics: false,
            target_qps: 0.0,
            retries: 0,
            backoff_ms: 50,
            mutate_frac: 0.0,
        }
    }
}

/// Parse a `--mix` string: `spmv:7,pagerank:3`.
pub fn parse_mix(text: &str) -> Result<Vec<(String, u32)>> {
    let mut mix = Vec::new();
    for part in text.split(',').filter(|p| !p.is_empty()) {
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => (n.trim().to_string(), w.trim().parse().context("bad mix weight")?),
            None => (part.trim().to_string(), 1),
        };
        if !matches!(name.as_str(), "spmv" | "pagerank" | "pr" | "sssp" | "tc") {
            bail!("unknown query {name:?} in mix (spmv|pagerank|sssp|tc)");
        }
        mix.push((name, weight));
    }
    if mix.is_empty() {
        bail!("empty query mix");
    }
    Ok(mix)
}

/// Result of one loadgen run.
#[derive(Clone, Debug)]
pub struct Report {
    /// Dataset and scheme the run targeted.
    pub dataset: String,
    /// Scheme name.
    pub scheme: String,
    /// Prepared-graph id on the server.
    pub id: String,
    /// Whether preparation was an LRU hit.
    pub cached: bool,
    /// Server-reported preparation time (ms; 0 on cache hits).
    pub prep_ms: f64,
    /// Queries attempted (excluding the ingest call). In coalesced mode
    /// each batch request carries several queries; this counts queries.
    pub requests: usize,
    /// Queries that failed (non-200 or transport error).
    pub failed: usize,
    /// Queries answered 429/503 by admission control — counts every
    /// rejection observed, including ones a later retry completed.
    pub rejected: usize,
    /// Queries answered 504 (deadline exceeded).
    pub deadline_exceeded: usize,
    /// Retry attempts performed after 429/503 rejections.
    pub retries: usize,
    /// Open-loop target offered load (0 = closed loop).
    pub target_qps: f64,
    /// Whether queries went through `POST /query/batch`.
    pub coalesced: bool,
    /// Queries per batch request (1 in single / direct-endpoint mode).
    pub batch: usize,
    /// Wall time of the query phase in seconds.
    pub elapsed_s: f64,
    /// Sustained throughput (completed queries / second; in coalesced
    /// mode each batch request completes `batch` queries).
    pub qps: f64,
    /// Latency mean over completed HTTP requests (ms) — a whole batch
    /// in coalesced mode.
    pub mean_ms: f64,
    /// Per-request latency p50 (ms).
    pub p50_ms: f64,
    /// Per-request latency p99 (ms).
    pub p99_ms: f64,
    /// Slowest request (ms).
    pub max_ms: f64,
    /// Fraction of request slots configured as mutations.
    pub mutate_frac: f64,
    /// `POST /mutate` batches durably acked during the run.
    pub mutations: usize,
    /// Server-side evidence from the pre/post `/metrics` scrape delta
    /// (`None` unless the run was configured with `scrape_metrics`).
    pub server: Option<Json>,
}

impl Report {
    /// JSON rendering (the `BENCH_serve.json` rows).
    pub fn to_json(&self) -> Json {
        let mut row = Json::obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("scheme", Json::Str(self.scheme.clone())),
            (
                "mode",
                Json::Str(if self.coalesced { "coalesced" } else { "single" }.to_string()),
            ),
            ("batch", Json::Num(self.batch as f64)),
            ("id", Json::Str(self.id.clone())),
            ("cached", Json::Bool(self.cached)),
            ("prep_ms", Json::Num(self.prep_ms)),
            ("requests", Json::Num(self.requests as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("deadline_exceeded", Json::Num(self.deadline_exceeded as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("target_qps", Json::Num(self.target_qps)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("qps", Json::Num(self.qps)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("max_ms", Json::Num(self.max_ms)),
            ("mutate_frac", Json::Num(self.mutate_frac)),
            ("mutations", Json::Num(self.mutations as f64)),
        ]);
        if let (Json::Obj(pairs), Some(server)) = (&mut row, &self.server) {
            pairs.push(("server".to_string(), server.clone()));
        }
        row
    }

    /// One-paragraph human rendering.
    pub fn render(&self) -> String {
        let resilience = if self.rejected > 0 || self.deadline_exceeded > 0 || self.retries > 0 {
            format!(
                " ({} rejected, {} deadline-exceeded, {} retries)",
                self.rejected, self.deadline_exceeded, self.retries
            )
        } else {
            String::new()
        };
        let churn = if self.mutations > 0 {
            format!(" ({} mutation batches acked)", self.mutations)
        } else {
            String::new()
        };
        format!(
            "{} via {}{}: {} queries over {:.2} s → {:.0} q/s \
             (p50 {:.3} ms, p99 {:.3} ms, max {:.3} ms, mean {:.3} ms), \
             {} failed{resilience}{churn}; prep {:.1} ms{}",
            self.dataset,
            self.scheme,
            if self.coalesced {
                format!(" (coalesced x{})", self.batch)
            } else if self.target_qps > 0.0 {
                format!(" (open-loop @ {:.0} q/s offered)", self.target_qps)
            } else {
                String::new()
            },
            self.requests,
            self.elapsed_s,
            self.qps,
            self.p50_ms,
            self.p99_ms,
            self.max_ms,
            self.mean_ms,
            self.failed,
            self.prep_ms,
            if self.cached { " (cached)" } else { "" },
        )
    }
}

/// Run one closed-loop load generation: prepare the graph, then hammer
/// it with the query mix from `conns` concurrent connections.
pub fn run(cfg: &LoadgenConfig) -> Result<Report> {
    // Pre-run scrape happens before the ingest so the delta captures
    // the cold prepare's per-stage times, not just the query phase.
    let pre_scrape =
        if cfg.scrape_metrics { Some(scrape_metrics(&cfg.addr)?) } else { None };

    // ── setup: ingest + prepare once ──────────────────────────────
    let mut setup = HttpClient::connect(&cfg.addr)
        .with_context(|| format!("loadgen connecting to {}", cfg.addr))?;
    let ingest_body = Json::obj(vec![
        ("dataset", Json::Str(cfg.dataset.clone())),
        ("scheme", Json::Str(cfg.scheme.clone())),
    ])
    .render();
    let (status, body) = setup.request_json("POST", "/graphs", &ingest_body)?;
    if status != 200 && status != 201 {
        bail!("ingest failed with {status}: {}", body.render());
    }
    let id = body
        .get("id")
        .and_then(Json::as_str)
        .context("ingest response missing id")?
        .to_string();
    let cached = body.get("cached").and_then(Json::as_bool).unwrap_or(false);
    let n = body.get("n").and_then(Json::as_u64).unwrap_or(0) as usize;
    let prep_ms = if cached {
        0.0
    } else {
        body.get("prep")
            .and_then(|p| p.get("total_ms"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    drop(setup);

    // ── query phase ───────────────────────────────────────────────
    let conns = cfg.conns.max(1);
    let batch = if cfg.coalesce { cfg.batch.max(1) } else { 1 };
    let remaining = AtomicUsize::new(cfg.requests);
    let pr_body = format!("{{\"iters\": {}}}", cfg.pr_iters);
    let total_weight: u32 = cfg.mix.iter().map(|(_, w)| w).sum();
    anyhow::ensure!(total_weight > 0, "query mix has zero total weight");

    struct WorkerOut {
        latencies_us: Vec<u64>,
        completed: usize,
        failed: usize,
        rejected: usize,
        deadline_exceeded: usize,
        retries: usize,
        mutations: usize,
    }

    // Open-loop pacing: each worker owns every conns-th slot of the
    // target arrival schedule. A late worker sends immediately and
    // never re-spaces, so offered load holds at the target.
    let gap_s = if cfg.target_qps > 0.0 { conns as f64 / cfg.target_qps } else { 0.0 };

    let sw = Stopwatch::start();
    // lint: allow(raw-spawn): loadgen is the *client* side — its
    // connection threads spend their lives blocked on sockets and must
    // not compete with (or deadlock) the server's compute pool inside
    // the same process during self-tests.
    let outs: Vec<WorkerOut> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..conns {
            let remaining = &remaining;
            let cfg = &*cfg;
            let id = &id;
            let pr_body = &pr_body;
            handles.push(scope.spawn(move || {
                let mut out = WorkerOut {
                    latencies_us: Vec::new(),
                    completed: 0,
                    failed: 0,
                    rejected: 0,
                    deadline_exceeded: 0,
                    retries: 0,
                    mutations: 0,
                };
                let start = Instant::now();
                let mut sent = 0usize;
                let mut client = match HttpClient::connect(&cfg.addr) {
                    Ok(c) => c,
                    Err(_) => return out, // counted below via remaining
                };
                let mut rng = Xoshiro256::stream(cfg.seed, w as u64 + 1);
                let mut draw = |rng: &mut Xoshiro256| -> &str {
                    let mut pick = rng.below(total_weight as u64) as u32;
                    let mut query = cfg.mix[0].0.as_str();
                    for (name, weight) in &cfg.mix {
                        if pick < *weight {
                            query = name.as_str();
                            break;
                        }
                        pick -= weight;
                    }
                    query
                };
                loop {
                    // Claim up to `batch` queries from the shared budget.
                    // ordering: SeqCst (both) — the budget is the only
                    // cross-thread handshake between loadgen workers;
                    // total order keeps claimed counts exact.
                    let take = match remaining.fetch_update(
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                        |r| (r > 0).then(|| r.saturating_sub(batch)),
                    ) {
                        Ok(prev) => prev.min(batch),
                        Err(_) => return out,
                    };
                    // Churn: some single-mode request slots become
                    // durable mutation batches instead of queries.
                    let mutate = !cfg.coalesce
                        && cfg.mutate_frac > 0.0
                        && n > 0
                        && rng.next_f64() < cfg.mutate_frac;
                    let (path, body) = if mutate {
                        (format!("/graphs/{id}/mutate"), mutate_body(&mut rng, n))
                    } else if cfg.coalesce {
                        // One POST /query/batch carrying `take` queries:
                        // the server answers the SpMV/SSSP portion with
                        // one multi-RHS kernel pass per ≤16-wide tile.
                        let items: Vec<String> = (0..take)
                            .map(|_| match draw(&mut rng) {
                                q @ ("pagerank" | "pr") => {
                                    format!("{{\"query\": \"{q}\", \"iters\": {}}}", cfg.pr_iters)
                                }
                                q => format!("{{\"query\": \"{q}\"}}"),
                            })
                            .collect();
                        (
                            "/query/batch".to_string(),
                            format!("{{\"id\": \"{id}\", \"queries\": [{}]}}", items.join(",")),
                        )
                    } else {
                        let query = draw(&mut rng);
                        let body = if matches!(query, "pagerank" | "pr") {
                            pr_body.clone()
                        } else {
                            String::new()
                        };
                        (format!("/graphs/{id}/{query}"), body)
                    };
                    if gap_s > 0.0 {
                        let due = start + Duration::from_secs_f64(gap_s * sent as f64);
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                    }
                    sent += 1;
                    let mut attempt = 0usize;
                    loop {
                        let lap = Stopwatch::start();
                        match client.request("POST", &path, body.as_bytes()) {
                            Ok((200, _)) => {
                                out.latencies_us.push(lap.elapsed().as_micros() as u64);
                                out.completed += take;
                                if mutate {
                                    out.mutations += 1;
                                }
                                break;
                            }
                            Ok((429 | 503, _)) => {
                                out.rejected += take;
                                if attempt >= cfg.retries {
                                    out.failed += take;
                                    break;
                                }
                                attempt += 1;
                                out.retries += 1;
                                // Jittered exponential backoff, floored
                                // at the server's Retry-After pricing.
                                let base = cfg.backoff_ms.max(1) << (attempt - 1).min(6);
                                let floor = client
                                    .retry_after()
                                    .map_or(0, |s| s.saturating_mul(1000));
                                let ms = base.max(floor);
                                // Deterministic jitter in [ms/2, 3ms/2).
                                let jittered = ms / 2 + rng.below(ms.max(1));
                                std::thread::sleep(Duration::from_millis(jittered));
                            }
                            Ok((504, _)) => {
                                out.deadline_exceeded += take;
                                out.failed += take;
                                break;
                            }
                            Ok((_, _)) => {
                                out.failed += take;
                                break;
                            }
                            Err(_) => {
                                out.failed += take;
                                // One reconnect attempt; give up on
                                // repeat failure.
                                match HttpClient::connect(&cfg.addr) {
                                    Ok(c) => client = c,
                                    Err(_) => return out,
                                }
                                break;
                            }
                        }
                    }
                }
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let elapsed_s = sw.elapsed().as_secs_f64();

    let mut latencies: Vec<u64> = Vec::new();
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut rejected = 0usize;
    let mut deadline_exceeded = 0usize;
    let mut retries = 0usize;
    let mut mutations = 0usize;
    for o in &outs {
        latencies.extend_from_slice(&o.latencies_us);
        completed += o.completed;
        failed += o.failed;
        rejected += o.rejected;
        deadline_exceeded += o.deadline_exceeded;
        retries += o.retries;
        mutations += o.mutations;
    }
    // Queries the workers never got to (early bail-outs) count as failed.
    let attempted = completed + failed;
    failed += cfg.requests.saturating_sub(attempted);
    latencies.sort_unstable();

    let pctl = |q: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((q * (latencies.len() - 1) as f64).round() as usize)
            .min(latencies.len() - 1);
        latencies[idx] as f64 / 1e3
    };
    let server = match pre_scrape {
        Some(pre) => Some(server_evidence(&pre, &scrape_metrics(&cfg.addr)?)),
        None => None,
    };
    Ok(Report {
        dataset: cfg.dataset.clone(),
        scheme: cfg.scheme.clone(),
        id,
        cached,
        prep_ms,
        requests: cfg.requests,
        failed,
        rejected,
        deadline_exceeded,
        retries,
        target_qps: cfg.target_qps,
        coalesced: cfg.coalesce,
        batch,
        elapsed_s,
        qps: if elapsed_s > 0.0 { completed as f64 / elapsed_s } else { 0.0 },
        mean_ms: if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().sum::<u64>() as f64 / latencies.len() as f64 / 1e3
        },
        p50_ms: pctl(0.50),
        p99_ms: pctl(0.99),
        max_ms: latencies.last().map_or(0.0, |&v| v as f64 / 1e3),
        mutate_frac: cfg.mutate_frac,
        mutations,
        server,
    })
}

/// Ops per `POST /mutate` batch the load generator sends.
const MUTATE_OPS: usize = 8;

/// Build one mutation batch. Vertex popularity is log-uniform over
/// `[0, n)` — a zipf-like skew (hubs churn far more often than the
/// tail) without per-draw harmonic sums — and roughly one op in four is
/// a delete, so tombstones and upserts both stay exercised.
fn mutate_body(rng: &mut Xoshiro256, n: usize) -> String {
    let zipf = |rng: &mut Xoshiro256| -> usize {
        (((n as f64).powf(rng.next_f64())) as usize).saturating_sub(1).min(n - 1)
    };
    let mut ops = Vec::with_capacity(MUTATE_OPS);
    for _ in 0..MUTATE_OPS {
        let (u, v) = (zipf(rng), zipf(rng));
        if rng.below(4) == 0 {
            ops.push(format!("{{\"op\": \"delete\", \"u\": {u}, \"v\": {v}}}"));
        } else {
            let w = rng.next_f32() * 4.0 + 0.25;
            ops.push(format!("{{\"op\": \"upsert\", \"u\": {u}, \"v\": {v}, \"w\": {w}}}"));
        }
    }
    format!("{{\"ops\": [{}]}}", ops.join(","))
}

/// Scrape and parse the server's `/metrics` exposition. The strict
/// parser makes every loadgen run with `--scrape-metrics` double as a
/// conformance check on the exposition format.
fn scrape_metrics(addr: &str) -> Result<crate::obs::text::Scrape> {
    let mut c =
        HttpClient::connect(addr).with_context(|| format!("scraping {addr}/metrics"))?;
    let (status, body) = c.request("GET", "/metrics", b"")?;
    anyhow::ensure!(status == 200, "GET /metrics answered {status}");
    crate::obs::text::Scrape::parse(&String::from_utf8_lossy(&body))
        .context("parsing /metrics exposition")
}

/// Diff two `/metrics` snapshots into the server-side evidence object
/// embedded in `BENCH_serve.json`: what the *server* measured while
/// this run was its traffic — latency percentiles free of client-side
/// queueing, the cold prepare's stage breakdown, and realized batch
/// widths.
fn server_evidence(pre: &crate::obs::text::Scrape, post: &crate::obs::text::Scrape) -> Json {
    use crate::obs::text::{histogram_delta, histogram_quantile};
    let mut eps = Vec::new();
    for ep in ["ingest", "spmv", "pagerank", "sssp", "tc", "batch"] {
        let labels = [("endpoint", ep)];
        let d = histogram_delta(
            &pre.histogram("boba_request_duration_seconds", &labels),
            &post.histogram("boba_request_duration_seconds", &labels),
        );
        let count = d.last().map_or(0.0, |b| b.1);
        if count < 1.0 {
            continue; // endpoint saw no traffic during this run
        }
        eps.push((
            ep.to_string(),
            Json::obj(vec![
                ("count", Json::Num(count)),
                ("p50_ms", Json::Num(histogram_quantile(&d, 0.50) * 1e3)),
                ("p99_ms", Json::Num(histogram_quantile(&d, 0.99) * 1e3)),
            ]),
        ));
    }
    let mut stages = Vec::new();
    for st in ["prepare.ingest", "prepare.reorder", "prepare.convert", "prepare.transpose"] {
        let labels = [("stage", st)];
        let sum = |s: &crate::obs::text::Scrape| {
            s.value("boba_stage_duration_seconds_sum", &labels).unwrap_or(0.0)
        };
        let cnt = |s: &crate::obs::text::Scrape| {
            s.value("boba_stage_duration_seconds_count", &labels).unwrap_or(0.0)
        };
        stages.push((
            st.to_string(),
            Json::obj(vec![
                ("count", Json::Num(cnt(post) - cnt(pre))),
                ("ms", Json::Num((sum(post) - sum(pre)) * 1e3)),
            ]),
        ));
    }
    let mut co = Vec::new();
    for kind in ["spmv", "sssp"] {
        let labels = [("kind", kind)];
        let d = histogram_delta(
            &pre.histogram("boba_coalesce_batch_width", &labels),
            &post.histogram("boba_coalesce_batch_width", &labels),
        );
        let batches = d.last().map_or(0.0, |b| b.1);
        let queries = post.value("boba_coalesce_batch_width_sum", &labels).unwrap_or(0.0)
            - pre.value("boba_coalesce_batch_width_sum", &labels).unwrap_or(0.0);
        co.push((
            kind.to_string(),
            Json::obj(vec![
                ("batches", Json::Num(batches)),
                ("queries", Json::Num(queries)),
                (
                    "mean_width",
                    Json::Num(if batches > 0.0 { queries / batches } else { 0.0 }),
                ),
            ]),
        ));
    }
    Json::obj(vec![
        ("endpoints", Json::Obj(eps)),
        ("prepare", Json::Obj(stages)),
        ("coalesce", Json::Obj(co)),
        (
            "rss_peak_bytes",
            Json::Num(
                post.value("boba_process_resident_memory_peak_bytes", &[]).unwrap_or(0.0),
            ),
        ),
    ])
}

/// The headline experiment: the same workload against `cfg.scheme`
/// (BOBA by default) and against the random-labels baseline
/// ([`super::registry::SCHEME_NONE`]). Returns `(reordered, baseline,
/// speedup)` where speedup is the throughput ratio.
pub fn compare(cfg: &LoadgenConfig) -> Result<(Report, Report, f64)> {
    let mut base_cfg = cfg.clone();
    base_cfg.scheme = super::registry::SCHEME_NONE.to_string();
    // Baseline first so the reordered run cannot benefit from warmer
    // caches on the server side.
    let baseline = run(&base_cfg)?;
    let reordered = run(cfg)?;
    let speedup = if baseline.qps > 0.0 { reordered.qps / baseline.qps } else { 0.0 };
    Ok((reordered, baseline, speedup))
}

/// Single-vs-coalesced pricing on the same scheme: the same workload
/// once through the direct endpoints (one query per request) and once
/// through `POST /query/batch` (`cfg.batch` queries per request, each
/// SpMV/SSSP tile one kernel pass). Returns `(single, coalesced,
/// speedup)` where speedup is the coalesced/single throughput ratio —
/// the serving-layer restatement of the spmm edge-stream amortization.
pub fn compare_coalesced(cfg: &LoadgenConfig) -> Result<(Report, Report, f64)> {
    let mut single_cfg = cfg.clone();
    single_cfg.coalesce = false;
    // Single first: the coalesced run then reuses the warmed artifact,
    // so the contrast isolates batching, not preparation.
    let single = run(&single_cfg)?;
    let mut co_cfg = cfg.clone();
    co_cfg.coalesce = true;
    let coalesced = run(&co_cfg)?;
    let speedup = if single.qps > 0.0 { coalesced.qps / single.qps } else { 0.0 };
    Ok((single, coalesced, speedup))
}

/// The churn experiment: the same workload once read-only (frozen
/// graph) and once with `mutate_frac` of the request slots sent as
/// durable `POST /mutate` batches — pricing what live mutation load
/// (WAL fsyncs, merged kernels over the delta overlay, background
/// compactions) costs the queries that share the server. Frozen runs
/// first so the mutating run inherits a warm artifact; the returned
/// section embeds both reports, the p50/p99/goodput ratios, and the
/// server's mutation/compaction counters scraped after the runs.
pub fn churn(cfg: &LoadgenConfig) -> Result<(Report, Report, Json)> {
    let mut frozen_cfg = cfg.clone();
    frozen_cfg.mutate_frac = 0.0;
    frozen_cfg.coalesce = false;
    let frozen = run(&frozen_cfg)?;
    let mut mut_cfg = frozen_cfg.clone();
    mut_cfg.mutate_frac = if cfg.mutate_frac > 0.0 { cfg.mutate_frac } else { 0.2 };
    let mutating = run(&mut_cfg)?;
    let scrape = scrape_metrics(&cfg.addr)?;
    let counter =
        |name: &str| scrape.value(name, &[]).unwrap_or(0.0);
    let ratio = |num: f64, den: f64| if den > 0.0 { num / den } else { 0.0 };
    let section = Json::obj(vec![
        ("bench", Json::Str("serve-churn".into())),
        ("frozen", frozen.to_json()),
        ("mutating", mutating.to_json()),
        ("mutate_frac", Json::Num(mut_cfg.mutate_frac)),
        ("goodput_ratio_mutating_vs_frozen", Json::Num(ratio(mutating.qps, frozen.qps))),
        ("p50_ratio_mutating_vs_frozen", Json::Num(ratio(mutating.p50_ms, frozen.p50_ms))),
        ("p99_ratio_mutating_vs_frozen", Json::Num(ratio(mutating.p99_ms, frozen.p99_ms))),
        ("server_mutations_total", Json::Num(counter("boba_mutations_total"))),
        ("server_compactions_total", Json::Num(counter("boba_compactions_total"))),
    ]);
    Ok((frozen, mutating, section))
}

/// Render a [`compare_coalesced`] result as its own document
/// (`loadgen --compare-coalesced`).
pub fn batch_comparison_json(single: &Report, coalesced: &Report, speedup: f64) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("serve-batch".into())),
        ("single", single.to_json()),
        ("coalesced", coalesced.to_json()),
        ("speedup_coalesced_qps", Json::Num(speedup)),
    ])
}

/// Render an overload sweep as the `overload` section of
/// `BENCH_serve.json`: the same open-loop overload (`target_qps`,
/// typically 2× measured capacity) against an admission-enabled server
/// and an unprotected one, plus the unloaded reference run the p99
/// degradation is priced against. The two derived ratios are the
/// resilience claims in number form: accepted-request p99 under
/// overload vs unloaded (admission should hold this near 1), and
/// admission goodput vs the unprotected baseline's.
pub fn overload_comparison_json(
    unloaded: &Report,
    capacity: &Report,
    admission: &Report,
    no_admission: &Report,
    target_qps: f64,
) -> Json {
    let ratio = |num: f64, den: f64| if den > 0.0 { num / den } else { 0.0 };
    Json::obj(vec![
        ("bench", Json::Str("serve-overload".into())),
        ("target_qps", Json::Num(target_qps)),
        ("unloaded", unloaded.to_json()),
        ("capacity", capacity.to_json()),
        ("admission", admission.to_json()),
        ("no_admission", no_admission.to_json()),
        (
            "p99_ratio_admission_vs_unloaded",
            Json::Num(ratio(admission.p99_ms, unloaded.p99_ms)),
        ),
        (
            "p99_ratio_no_admission_vs_unloaded",
            Json::Num(ratio(no_admission.p99_ms, unloaded.p99_ms)),
        ),
        (
            "goodput_ratio_admission_vs_no_admission",
            Json::Num(ratio(admission.qps, no_admission.qps)),
        ),
    ])
}

/// Render the comparison as the `BENCH_serve.json` document. The
/// optional `coalesced` triple appends the single-vs-coalesced rows
/// ([`compare_coalesced`] on the reordered scheme) so one document
/// prices both axes: reordering and batching.
pub fn comparison_json(
    reordered: &Report,
    baseline: &Report,
    speedup: f64,
    coalesced: Option<(&Report, f64)>,
) -> Json {
    let mut pairs = vec![
        ("bench".to_string(), Json::Str("serve".into())),
        ("reordered".to_string(), reordered.to_json()),
        ("baseline".to_string(), baseline.to_json()),
        ("speedup_qps".to_string(), Json::Num(speedup)),
    ];
    if let Some((co, co_speedup)) = coalesced {
        pairs.push(("coalesced".to_string(), co.to_json()));
        pairs.push(("speedup_coalesced_qps".to_string(), Json::Num(co_speedup)));
    }
    Json::Obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_parses() {
        let m = parse_mix("spmv:7,pagerank:3").unwrap();
        assert_eq!(m, vec![("spmv".to_string(), 7), ("pagerank".to_string(), 3)]);
        let single = parse_mix("tc").unwrap();
        assert_eq!(single, vec![("tc".to_string(), 1)]);
        assert!(parse_mix("").is_err());
        assert!(parse_mix("frobnicate:2").is_err());
        assert!(parse_mix("spmv:x").is_err());
    }

    #[test]
    fn churn_against_wal_enabled_server() {
        let dir =
            std::env::temp_dir().join(format!("boba-loadgen-churn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let server = crate::server::spawn(crate::server::ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 3,
            capacity: 4,
            batch: 4096,
            in_flight: 2,
            seed: 17,
            read_timeout: std::time::Duration::from_secs(10),
            wal_dir: Some(dir.clone()),
            compact_threshold: 64, // background compaction under churn
            ..Default::default()
        })
        .unwrap();
        let cfg = LoadgenConfig {
            addr: server.addr().to_string(),
            conns: 2,
            requests: 40,
            dataset: "pa:2000:4".to_string(),
            mix: vec![("spmv".to_string(), 3), ("sssp".to_string(), 1)],
            seed: 7,
            mutate_frac: 0.5,
            ..Default::default()
        };
        let (frozen, mutating, section) = churn(&cfg).unwrap();
        assert_eq!(frozen.mutations, 0, "frozen leg must stay read-only");
        assert_eq!(frozen.failed, 0, "{frozen:?}");
        assert!(mutating.mutations > 0, "half the slots mutate: {mutating:?}");
        assert_eq!(mutating.failed, 0, "{mutating:?}");
        let rendered = section.render();
        for field in [
            "\"bench\":\"serve-churn\"",
            "goodput_ratio_mutating_vs_frozen",
            "p99_ratio_mutating_vs_frozen",
            "server_mutations_total",
            "server_compactions_total",
        ] {
            assert!(rendered.contains(field), "missing {field} in {rendered}");
        }
        server.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn end_to_end_against_in_process_server() {
        let server = crate::server::spawn(crate::server::ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 3,
            capacity: 4,
            batch: 4096,
            in_flight: 2,
            seed: 13,
            read_timeout: std::time::Duration::from_secs(10),
            ..Default::default()
        })
        .unwrap();
        let cfg = LoadgenConfig {
            addr: server.addr().to_string(),
            conns: 2,
            requests: 40,
            dataset: "pa:3000:4".to_string(),
            scheme: "boba".to_string(),
            mix: vec![("spmv".to_string(), 3), ("pagerank".to_string(), 1)],
            pr_iters: 3,
            seed: 99,
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 40);
        assert_eq!(report.failed, 0, "no request may fail: {report:?}");
        assert!(report.qps > 0.0);
        assert!(report.p99_ms >= report.p50_ms);
        assert!(!report.cached);
        assert!(!report.coalesced);
        assert_eq!(report.batch, 1);
        // A second run hits the artifact cache.
        let again = run(&cfg).unwrap();
        assert!(again.cached);

        // Coalesced mode: same workload through /query/batch, 5 queries
        // per request (40 = 8 batches), every query must succeed.
        let co_cfg = LoadgenConfig { coalesce: true, batch: 5, ..cfg.clone() };
        let co = run(&co_cfg).unwrap();
        assert_eq!(co.requests, 40);
        assert_eq!(co.failed, 0, "no batched query may fail: {co:?}");
        assert!(co.coalesced);
        assert_eq!(co.batch, 5);
        assert!(co.qps > 0.0);
        // The server-side width histogram saw multi-query tiles.
        assert!(server.coalescer.spmv_widths().queries() > 0);

        // The JSON rows carry the mode tag the CI grep keys on.
        let j = co.to_json().render();
        assert!(j.contains("\"mode\":\"coalesced\""), "{j}");
        assert!(run(&cfg).unwrap().to_json().render().contains("\"mode\":\"single\""));

        // Open-loop pacing on the now-cached artifact: every query
        // still succeeds, and the row carries the resilience fields the
        // CI overload gate greps for.
        let open_cfg = LoadgenConfig { target_qps: 500.0, requests: 30, ..cfg.clone() };
        let open = run(&open_cfg).unwrap();
        assert_eq!(open.failed, 0, "open-loop at a modest target must not fail: {open:?}");
        assert_eq!(open.target_qps, 500.0);
        let oj = open.to_json().render();
        for field in ["\"rejected\":", "\"deadline_exceeded\":", "\"retries\":", "\"target_qps\":"] {
            assert!(oj.contains(field), "{oj}");
        }

        // Scrape mode: a cold dataset so the pre/post delta captures
        // the prepare stages, not just the query traffic. Stage spans
        // ride the process-global tracing flag, which the obs
        // kill-switch test flips momentarily — retry on a fresh cold
        // dataset if a prepare raced that window.
        let mut scraped = None;
        for attempt in 0..3 {
            crate::obs::set_enabled(true);
            let scrape_cfg = LoadgenConfig {
                dataset: format!("pa:{}:4", 2500 + attempt),
                requests: 20,
                scrape_metrics: true,
                ..cfg.clone()
            };
            let report = run(&scrape_cfg).unwrap();
            let evidence = report.server.as_ref().expect("scrape evidence embedded");
            let traced = ["prepare.ingest", "prepare.reorder", "prepare.convert", "prepare.transpose"]
                .iter()
                .all(|st| {
                    evidence
                        .get("prepare")
                        .and_then(|p| p.get(st))
                        .and_then(|s| s.get("count"))
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0)
                        >= 1.0
                });
            if traced {
                scraped = Some(report);
                break;
            }
        }
        let scraped = scraped.expect("a fully traced cold prepare within three attempts");
        let server_side = scraped.server.as_ref().unwrap();
        let spmv = server_side.get("endpoints").unwrap().get("spmv").unwrap();
        assert!(spmv.get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(
            spmv.get("p99_ms").unwrap().as_f64().unwrap()
                >= spmv.get("p50_ms").unwrap().as_f64().unwrap()
        );
        assert!(server_side.get("coalesce").unwrap().get("spmv").is_some());
        let rendered = scraped.to_json().render();
        assert!(rendered.contains("\"server\""), "{rendered}");
        assert!(rendered.contains("prepare.transpose"), "{rendered}");
        server.shutdown();
    }
}
