//! A minimal HTTP/1.1 layer over std I/O traits — just enough protocol
//! for the service layer: request-line + headers + `Content-Length`
//! bodies, persistent (keep-alive) connections, and a tiny client used
//! by [`super::loadgen`] and the integration tests.
//!
//! Deliberately unsupported (a 400 is returned instead): chunked
//! transfer encoding, HTTP/2, multi-line headers, trailers. The service
//! speaks only to its own loadgen and to curl-style tools, and both
//! send simple framed requests.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Upper bound on the request line + headers (DoS guard).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Upper bound on a request/response body (DoS guard).
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Upper-case method (`GET`, `POST`, ...).
    pub method: String,
    /// Path component of the target, query string stripped.
    pub path: String,
    /// Raw query string ("" when absent).
    pub query: String,
    /// Headers with lower-cased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Request body (empty when no `Content-Length`).
    pub body: Vec<u8>,
}

impl Request {
    /// First header value by lower-case name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to drop the connection after this
    /// exchange (`Connection: close`).
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map_or(false, |v| v.eq_ignore_ascii_case("close"))
    }

    /// Body as UTF-8 (lossy — bodies here are JSON, already ASCII-safe).
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Read one request from a buffered stream.
///
/// Returns `Ok(None)` when no request is forthcoming — clean EOF, or a
/// read timeout firing *before the first byte* (an idle keep-alive
/// connection; answering it would desynchronize the client's
/// request/response pairing). Errors on malformed or oversized input
/// and on timeouts mid-request.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>> {
    let mut line = String::new();
    match r.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e)
            if line.is_empty()
                && matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
        {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .context("empty request line")?
        .to_ascii_uppercase();
    let target = parts.next().context("request line missing target")?;
    let version = parts.next().context("request line missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version {version}");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers = Vec::new();
    let mut header_bytes = line.len();
    loop {
        let mut hl = String::new();
        if r.read_line(&mut hl)? == 0 {
            bail!("connection closed mid-headers");
        }
        header_bytes += hl.len();
        if header_bytes > MAX_HEADER_BYTES {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let t = hl.trim_end_matches(|c| c == '\r' || c == '\n');
        if t.is_empty() {
            break;
        }
        let (name, value) = t
            .split_once(':')
            .with_context(|| format!("malformed header line {t:?}"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    if headers.iter().any(|(k, _)| k == "transfer-encoding") {
        bail!("chunked transfer encoding not supported");
    }
    let len: usize = match headers.iter().find(|(k, _)| k == "content-length") {
        Some((_, v)) => v.parse().context("bad content-length")?,
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        bail!("body of {len} bytes exceeds {MAX_BODY_BYTES}");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading request body")?;

    Ok(Some(Request { method, path, query, headers, body }))
}

/// Reason phrase for the status codes the service emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// An HTTP response ready to serialize.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body.
    pub body: Vec<u8>,
    /// Whether to close the connection after writing.
    pub close: bool,
    /// Additional response headers (name, value) — e.g. `x-request-id`.
    /// Names must be lower-case ASCII; values must be header-safe (no
    /// CR/LF). Framing headers (content-*, connection) are managed by
    /// [`Self::write_to`] and must not appear here.
    pub extra: Vec<(String, String)>,
}

impl Response {
    /// JSON response.
    pub fn json(status: u16, body: impl Into<Vec<u8>>) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into(),
            close: false,
            extra: Vec::new(),
        }
    }

    /// Plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            close: false,
            extra: Vec::new(),
        }
    }

    /// Plain-text response with an explicit content type (the
    /// `/metrics` exposition advertises `text/plain; version=0.0.4`).
    pub fn text_with_type(
        status: u16,
        content_type: &'static str,
        body: impl Into<String>,
    ) -> Response {
        Response {
            status,
            content_type,
            body: body.into().into_bytes(),
            close: false,
            extra: Vec::new(),
        }
    }

    /// Append an extra response header.
    pub fn with_header(mut self, name: &str, value: String) -> Response {
        self.extra.push((name.to_string(), value));
        self
    }

    /// JSON error envelope: `{"error": "..."}`.
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(
            status,
            format!(
                "{{\"error\": {}}}",
                super::json::Json::Str(message.to_string()).render()
            ),
        )
    }

    /// Serialize status line, framing headers, extra headers, and body.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if self.close { "close" } else { "keep-alive" },
        )?;
        for (name, value) in &self.extra {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)
    }
}

/// A blocking HTTP/1.1 client over one persistent TCP connection —
/// the loadgen worker's and the smoke test's view of the server.
///
/// Every stream carries connect, read, **and** write timeouts (see
/// [`HttpClient::connect_timeout`]): a stalled or unresponsive server
/// turns into an error the caller can retry, never a benchmark that
/// hangs forever.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    /// `Retry-After` (integer seconds) from the most recent response,
    /// if the server sent one — 429/503 rejections price their own
    /// backoff and the loadgen retry loop honors it.
    retry_after: Option<u64>,
}

/// Default connect timeout for [`HttpClient::connect`].
pub const DEFAULT_CONNECT_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);
/// Default read/write timeout for [`HttpClient::connect`] — generous
/// because prepares of large datasets legitimately take a while.
pub const DEFAULT_IO_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(120);

impl HttpClient {
    /// Connect to `addr` (e.g. `127.0.0.1:7171`) with the default
    /// timeouts.
    pub fn connect(addr: &str) -> Result<HttpClient> {
        Self::connect_timeout(addr, DEFAULT_CONNECT_TIMEOUT, DEFAULT_IO_TIMEOUT)
    }

    /// Connect with explicit timeouts: `connect` bounds the TCP
    /// handshake, `io` bounds every subsequent read and write. A write
    /// timeout matters as much as the read one — a server that stops
    /// draining its socket would otherwise park the client in `write`
    /// forever once the kernel buffers fill.
    pub fn connect_timeout(
        addr: &str,
        connect: std::time::Duration,
        io: std::time::Duration,
    ) -> Result<HttpClient> {
        use std::net::ToSocketAddrs;
        let sa = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("{addr} resolved to no address"))?;
        let stream = TcpStream::connect_timeout(&sa, connect)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(io)).ok();
        stream.set_write_timeout(Some(io)).ok();
        Ok(HttpClient { reader: BufReader::new(stream), retry_after: None })
    }

    /// `Retry-After` seconds from the most recent response, if any.
    pub fn retry_after(&self) -> Option<u64> {
        self.retry_after
    }

    /// Issue one request, reusing the connection. Returns
    /// `(status, body)`.
    pub fn request(&mut self, method: &str, path: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
        {
            let mut w = self.reader.get_ref();
            write!(
                w,
                "{method} {path} HTTP/1.1\r\nhost: boba\r\ncontent-length: {}\r\n\r\n",
                body.len()
            )?;
            w.write_all(body)?;
            w.flush()?;
        }

        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed connection before responding");
        }
        let mut parts = line.split_whitespace();
        let version = parts.next().context("empty status line")?;
        if !version.starts_with("HTTP/1.") {
            bail!("unexpected response protocol {version}");
        }
        let status: u16 = parts
            .next()
            .context("status line missing code")?
            .parse()
            .context("bad status code")?;

        let mut content_length: Option<usize> = None;
        let mut close = false;
        self.retry_after = None;
        loop {
            let mut hl = String::new();
            if self.reader.read_line(&mut hl)? == 0 {
                bail!("connection closed mid-response-headers");
            }
            let t = hl.trim_end_matches(|c| c == '\r' || c == '\n');
            if t.is_empty() {
                break;
            }
            if let Some((name, value)) = t.split_once(':') {
                let name = name.trim().to_ascii_lowercase();
                let value = value.trim();
                if name == "content-length" {
                    content_length = Some(value.parse().context("bad content-length")?);
                } else if name == "connection" && value.eq_ignore_ascii_case("close") {
                    close = true;
                } else if name == "retry-after" {
                    self.retry_after = value.parse().ok();
                }
            }
        }

        let resp_body = match content_length {
            Some(len) => {
                anyhow::ensure!(len <= MAX_BODY_BYTES, "response body too large");
                let mut b = vec![0u8; len];
                self.reader.read_exact(&mut b).context("reading response body")?;
                b
            }
            None => {
                // Delimited by connection close (we never send this, but
                // tolerate it from other servers).
                let mut b = Vec::new();
                self.reader.read_to_end(&mut b)?;
                b
            }
        };
        if close {
            bail!("server closed connection (status {status})");
        }
        Ok((status, resp_body))
    }

    /// Convenience: issue a request and parse the JSON body.
    pub fn request_json(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> Result<(u16, super::json::Json)> {
        let (status, raw) = self.request(method, path, body.as_bytes())?;
        let text = String::from_utf8_lossy(&raw);
        let json = super::json::Json::parse(&text)
            .with_context(|| format!("non-JSON body from {method} {path}: {text:?}"))?;
        Ok((status, json))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse("GET /stats?format=text HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/stats");
        assert_eq!(r.query, "format=text");
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());
        assert!(!r.wants_close());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let r = parse(
            "POST /graphs HTTP/1.1\r\nContent-Length: 9\r\nConnection: close\r\n\r\n{\"a\": 1}x",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body_str(), "{\"a\": 1}x");
        assert!(r.wants_close());
    }

    #[test]
    fn eof_before_request_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("BANANAS\r\n\r\n").is_err());
        assert!(parse("GET / SMTP/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nbroken header\r\n\r\n").is_err());
        assert!(parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").is_err());
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n").is_err());
    }

    #[test]
    fn response_serializes_with_framing() {
        let resp = Response::json(200, "{\"ok\": true}");
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("content-length: 12\r\n"));
        assert!(text.contains("connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\": true}"));
    }

    #[test]
    fn extra_headers_are_emitted_before_the_blank_line() {
        let resp = Response::json(200, "{}").with_header("x-request-id", "r-17".to_string());
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let (head, body) = text.split_once("\r\n\r\n").unwrap();
        assert!(head.contains("\r\nx-request-id: r-17"));
        assert_eq!(body, "{}");
    }

    #[test]
    fn error_response_is_json() {
        let resp = Response::error(404, "no such graph \"x\"");
        let body = String::from_utf8(resp.body).unwrap();
        assert!(body.starts_with("{\"error\":"));
        assert!(body.contains("\\\"x\\\""));
    }

    #[test]
    fn unresponsive_server_times_out_instead_of_hanging() {
        // A listener that accepts the connection and then never reads
        // nor answers — the client's I/O timeout must surface an error
        // in bounded time (the pre-timeout client hung here forever).
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let hold = std::thread::spawn(move || {
            let (_sock, _) = listener.accept().unwrap();
            let _ = rx.recv(); // hold the socket open, never answering
        });
        let mut c = HttpClient::connect_timeout(
            &addr,
            std::time::Duration::from_secs(5),
            std::time::Duration::from_millis(200),
        )
        .unwrap();
        let sw = std::time::Instant::now();
        assert!(
            c.request("GET", "/healthz", b"").is_err(),
            "an unanswered request must error, not hang"
        );
        assert!(
            sw.elapsed() < std::time::Duration::from_secs(3),
            "the error must arrive near the configured timeout, took {:?}",
            sw.elapsed()
        );
        drop(tx);
        hold.join().unwrap();
    }
}
