//! The graph registry: ingest a dataset once, run the Problem-3
//! pipeline (batched COO ingest → reorder → CSR conversion), and cache
//! the prepared artifact for every subsequent query.
//!
//! This is the amortization argument for lightweight reordering made
//! concrete (Faldu et al.: reordering pays when its one-time cost is
//! spread over many traversals): the reorder+convert cost is paid at
//! `POST /graphs` time, and every `POST /graphs/{id}/<query>` after
//! that runs on the locality-optimized CSR for free.
//!
//! Cache policy is LRU keyed by `(dataset, scheme)` — the same dataset
//! prepared under two schemes is two artifacts, which is exactly what
//! the BOBA-vs-random serving comparison needs. Recency is a monotonic
//! per-entry counter (touch = one store under the lock, eviction = a
//! min-recency scan at insert time only), so the query hot path does
//! O(1) work inside the registry mutex. Preparation is **single-flight**:
//! N concurrent requesters for a cold key run the pipeline exactly once
//! — the first installs an in-flight marker, the rest park on its
//! condvar and share the result.

use crate::convert;
use crate::coordinator::datasets;
use crate::coordinator::pipeline::StreamingIngest;
use crate::graph::{Coo, Csr};
use crate::reorder::{self, Permutation};
use crate::util::deadline;
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, Context, Result};
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use super::live::LiveGraph;
use super::wal;

use super::json::Json;

/// Scheme name meaning "serve the randomized labels as-is" (the paper's
/// Random baseline).
pub const SCHEME_NONE: &str = "none";

/// Stage timings of one preparation run (the served Fig-4 bar).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrepReport {
    /// Batched-ingest wall time (ms) and batch count.
    pub ingest_ms: f64,
    /// Batches consumed from the streaming producer.
    pub batches: usize,
    /// Reorder (+fused relabel) wall time, 0 for [`SCHEME_NONE`].
    pub reorder_ms: f64,
    /// COO→CSR conversion wall time.
    pub convert_ms: f64,
    /// Transpose (`Aᵀ` structure) wall time — the pull operand cached
    /// for PageRank.
    pub transpose_ms: f64,
    /// Kernel-format encode + equivalence-probe wall time, 0 when the
    /// registry serves plain CSR only (no `--format`).
    pub format_ms: f64,
}

impl PrepReport {
    /// Total preparation time in milliseconds.
    pub fn total_ms(&self) -> f64 {
        self.ingest_ms + self.reorder_ms + self.convert_ms + self.transpose_ms + self.format_ms
    }

    /// JSON rendering for ingest responses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ingest_ms", Json::Num(self.ingest_ms)),
            ("batches", Json::Num(self.batches as f64)),
            ("reorder_ms", Json::Num(self.reorder_ms)),
            ("convert_ms", Json::Num(self.convert_ms)),
            ("transpose_ms", Json::Num(self.transpose_ms)),
            ("format_ms", Json::Num(self.format_ms)),
            ("total_ms", Json::Num(self.total_ms())),
        ])
    }
}

/// Lazily built triangle-counting view of a prepared graph
/// (symmetrized, deduped, degree-rank-oriented — what `pipeline`'s TC
/// stage builds per run, built here once per artifact).
pub struct TcView {
    /// Oriented DAG with sorted adjacency lists.
    pub dag: Csr,
    /// Degree rank used for orientation.
    pub rank: Vec<u32>,
}

/// One cached, query-ready artifact.
pub struct PreparedGraph {
    /// Registry id, `dataset@scheme`.
    pub id: String,
    /// Dataset spec it was built from.
    pub dataset: String,
    /// Reordering scheme name ([`SCHEME_NONE`] for the baseline).
    pub scheme: String,
    /// The CSR every query runs on.
    pub csr: Arc<Csr>,
    /// The transpose structure (`Aᵀ`), built eagerly at prepare time —
    /// PageRank's pull operand, cached so repeated queries skip the
    /// per-call O(m) transpose (ROADMAP's first-class-transpose item).
    pub transpose: Arc<Csr>,
    /// Old→new relabeling applied (None for [`SCHEME_NONE`]).
    pub perm: Option<Arc<Permutation>>,
    /// Optional compressed kernel-format variant (`serve --format`),
    /// encoded from the served CSR and verified **bit-identical** to
    /// `spmv_pull` at prepare time — exposed on `/metrics` as
    /// `boba_format_bytes_per_edge`.
    pub format: Option<Arc<dyn crate::runtime::format::SpmvFormat>>,
    /// Stage timings of the preparation run.
    pub prep: PrepReport,
    /// Mutation epoch: 0 for a fresh prepare, bumped by every
    /// compaction that folds the delta overlay into a rebuilt artifact
    /// (see [`super::live`]). Queries snapshot `(artifact, epoch)`
    /// atomically, so an in-flight query finishes on the epoch it was
    /// admitted on even while the compactor swaps.
    pub epoch: u64,
    /// Queries served from this artifact.
    pub queries: AtomicU64,
    /// Label-invariant SSSP default source (max total degree), computed
    /// on first use.
    default_source: OnceLock<u32>,
    /// TC view, computed on first `tc` query.
    tc: OnceLock<Arc<TcView>>,
}

impl PreparedGraph {
    /// Vertices.
    pub fn n(&self) -> usize {
        self.csr.n()
    }

    /// Edges.
    pub fn m(&self) -> usize {
        self.csr.m()
    }

    /// Default SSSP source: the max-total-degree vertex — label
    /// invariant, so digests compare across schemes (mirrors
    /// `pipeline::Pipeline::run_app`).
    pub fn default_source(&self) -> u32 {
        *self.default_source.get_or_init(|| {
            let csr = &*self.csr;
            let mut total: Vec<u64> = (0..csr.n()).map(|v| csr.degree(v) as u64).collect();
            for &c in &csr.col_idx {
                total[c as usize] += 1;
            }
            (0..csr.n()).max_by_key(|&v| total[v]).unwrap_or(0) as u32
        })
    }

    /// The TC view, building it on first use. Reconstructs an edge list
    /// from the served CSR, then applies the same symmetrize → dedup →
    /// sort-by-src → convert → orient pipeline the offline TC stage
    /// runs (`pipeline.rs`), so served counts match the CLI's. The
    /// parallel converter is deterministic and stable, so the sorted COO
    /// yields sorted rows with no `sort_rows` compensation.
    pub fn tc_view(&self) -> Arc<TcView> {
        self.tc
            .get_or_init(|| {
                use crate::algos::tc;
                let und = convert::csr_to_coo(&self.csr).symmetrized().deduped();
                let sorted = convert::sort_coo_by_src(&und);
                let csr = convert::coo_to_csr_parallel(&sorted);
                let rank = tc::degree_rank(&csr);
                let dag = tc::orient_by_rank(&csr, &rank);
                Arc::new(TcView { dag, rank })
            })
            .clone()
    }

    /// JSON row for `GET /graphs`.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Str(self.id.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("scheme", Json::Str(self.scheme.clone())),
            ("n", Json::Num(self.n() as f64)),
            ("m", Json::Num(self.m() as f64)),
            ("epoch", Json::Num(self.epoch as f64)),
            ("queries", Json::Num(self.queries.load(Ordering::Relaxed) as f64)),
            ("prep", self.prep.to_json()),
        ];
        if let Some(f) = &self.format {
            fields.push(("format", Json::Str(f.name().to_string())));
            fields.push(("format_bytes_per_edge", Json::Num(f.bytes_per_edge())));
        }
        Json::obj(fields)
    }
}

/// Registry configuration.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// LRU capacity in prepared artifacts.
    pub capacity: usize,
    /// Streaming-ingest batch size (edges per batch).
    pub batch: usize,
    /// Streaming-ingest channel capacity (batches in flight).
    pub in_flight: usize,
    /// Seed for dataset generation and label randomization.
    pub seed: u64,
    /// Kernel format to encode for every prepared artifact (a
    /// [`crate::runtime::format::FORMAT_NAMES`] name); `None` serves
    /// plain CSR only.
    pub format: Option<String>,
    /// Directory for mutation WALs, checkpoints, and recovery metas
    /// (`serve --wal-dir`). `None` disables `POST /mutate` entirely.
    pub wal_dir: Option<PathBuf>,
    /// Overlay size (upserts + tombstones) at which a mutation batch
    /// triggers background compaction; 0 disables the trigger (manual
    /// `POST /graphs/{id}/compact` still works).
    pub compact_threshold: usize,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            capacity: 8,
            batch: 1 << 16,
            in_flight: 4,
            seed: 42,
            format: None,
            wal_dir: None,
            compact_threshold: 4096,
        }
    }
}

/// A prepare in flight: waiters block on the condvar until the one
/// thread running the pipeline publishes its outcome. Errors cross as
/// rendered strings (`anyhow::Error` is not `Clone`).
struct InFlight {
    done: Mutex<Option<std::result::Result<Arc<PreparedGraph>, String>>>,
    cv: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { done: Mutex::new(None), cv: Condvar::new() }
    }

    /// Park until the leader publishes — or the *waiter's* thread-local
    /// [`deadline`] runs out first, in which case it detaches with an
    /// error of its own. Detaching never touches the leader: the
    /// pipeline keeps running and publishes for the remaining waiters
    /// (and the cache) as usual. The 250 ms poll bounds the
    /// no-deadline case without busy-waiting.
    fn wait(&self) -> std::result::Result<Arc<PreparedGraph>, String> {
        let mut d = self.done.lock().unwrap();
        loop {
            if let Some(r) = d.as_ref() {
                return r.clone();
            }
            let budget = deadline::remaining().unwrap_or(Duration::from_millis(250));
            if budget.is_zero() {
                return Err("deadline exceeded while joining an in-flight prepare".to_string());
            }
            let (dd, _timeout) =
                self.cv.wait_timeout(d, budget.min(Duration::from_millis(250))).unwrap();
            d = dd;
        }
    }

    fn publish(&self, r: std::result::Result<Arc<PreparedGraph>, String>) {
        *self.done.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }
}

/// One registry map slot: a prepared artifact with its LRU recency
/// stamp, or an in-flight marker other requesters join instead of
/// re-running the pipeline.
enum Slot {
    Ready { graph: Arc<PreparedGraph>, recency: u64 },
    Pending(Arc<InFlight>),
}

struct Inner {
    map: HashMap<String, Slot>,
    /// Monotonic recency clock: every lookup stamps its entry with the
    /// next tick, so a *touch* is O(1) inside the lock (the old
    /// `VecDeque` order list cost an O(n) scan per query hit) and
    /// eviction is a min-recency scan at insert time only.
    clock: u64,
}

impl Inner {
    fn ready_count(&self) -> usize {
        self.map.values().filter(|s| matches!(s, Slot::Ready { .. })).count()
    }
}

/// The concurrent LRU registry of prepared graphs (single-flight: N
/// concurrent requesters for a cold key run the pipeline exactly once).
pub struct GraphRegistry {
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
    /// Live (mutable) state per artifact id — created lazily on first
    /// `POST /mutate` (or by WAL recovery) and never evicted: the WAL
    /// on disk is the durable identity, the map entry just caches its
    /// open handle.
    live: Mutex<HashMap<String, Arc<LiveGraph>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    prepares: AtomicU64,
    /// Completed compactions (`boba_compactions_total`).
    compactions: AtomicU64,
    /// Compactor threads currently running.
    active_compactions: AtomicU64,
    /// Graphs still replaying their WAL at startup — `/readyz` reports
    /// `recovering` while this is non-zero.
    recovering: AtomicUsize,
    /// Set once the first prepare completes successfully — before that,
    /// a pending prepare means the server has nothing to serve yet and
    /// `/readyz` reports it (see [`Self::mid_first_prepare`]).
    first_ready: AtomicBool,
}

/// Removes the pending marker and publishes a failure if the preparing
/// thread unwinds (a panicking pipeline must not leave waiters parked
/// forever or the key permanently uncacheable).
struct PendingGuard<'a> {
    registry: &'a GraphRegistry,
    id: &'a str,
    flight: &'a Arc<InFlight>,
    armed: bool,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            let mut inner = self.registry.inner.lock().unwrap();
            if matches!(inner.map.get(self.id), Some(Slot::Pending(_))) {
                inner.map.remove(self.id);
            }
            drop(inner);
            self.flight.publish(Err("prepare panicked".to_string()));
        }
    }
}

impl GraphRegistry {
    /// New registry.
    pub fn new(cfg: RegistryConfig) -> GraphRegistry {
        GraphRegistry {
            cfg,
            inner: Mutex::new(Inner { map: HashMap::new(), clock: 0 }),
            live: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prepares: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
            active_compactions: AtomicU64::new(0),
            recovering: AtomicUsize::new(0),
            first_ready: AtomicBool::new(false),
        }
    }

    /// True while a prepare is in flight and *no* prepare has ever
    /// completed: the server holds zero queryable artifacts and is
    /// about to hold one, which `/readyz` reports as not-ready so
    /// orchestrators delay traffic instead of eating cold 404s. Later
    /// prepares (the cache already serves) never degrade readiness.
    pub fn mid_first_prepare(&self) -> bool {
        if self.first_ready.load(Ordering::Relaxed) {
            return false;
        }
        let inner = self.inner.lock().unwrap();
        inner.map.values().any(|s| matches!(s, Slot::Pending(_)))
    }

    /// Registry id for a (dataset, scheme) pair.
    pub fn id_of(dataset: &str, scheme: &str) -> String {
        format!("{dataset}@{scheme}")
    }

    /// Cached artifact by id, touching LRU recency — O(1) inside the
    /// lock (the query hot path). Does not move the hit/miss counters —
    /// those track *prepare-cache* outcomes (see
    /// [`Self::get_or_prepare`]), not query lookups. In-flight prepares
    /// are not yet queryable and return `None`.
    pub fn get(&self, id: &str) -> Option<Arc<PreparedGraph>> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(id) {
            Some(Slot::Ready { graph, recency }) => {
                *recency = clock;
                Some(graph.clone())
            }
            _ => None,
        }
    }

    /// Cached artifact, or prepare-and-insert. Returns `(graph, cached)`
    /// where `cached` is true on an LRU hit (including joining a prepare
    /// another requester already has in flight).
    ///
    /// Single-flight: the first requester for a cold key installs an
    /// in-flight marker and runs the Problem-3 pipeline *outside* the
    /// registry lock; every concurrent requester for the same key parks
    /// on the marker's condvar and shares the one result (losers wait,
    /// then hit — they count as hits, not misses). Requesters for
    /// *other* keys are never stalled. A failed prepare clears the
    /// marker (waiters get the error; the next requester retries).
    pub fn get_or_prepare(&self, dataset: &str, scheme: &str) -> Result<(Arc<PreparedGraph>, bool)> {
        let id = Self::id_of(dataset, scheme);
        let flight: Arc<InFlight>;
        {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let clock = inner.clock;
            match inner.map.get_mut(&id) {
                Some(Slot::Ready { graph, recency }) => {
                    *recency = clock;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((graph.clone(), true));
                }
                Some(Slot::Pending(f)) => {
                    flight = f.clone();
                }
                None => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    let f = Arc::new(InFlight::new());
                    inner.map.insert(id.clone(), Slot::Pending(f.clone()));
                    drop(inner);
                    return self.run_prepare(&id, dataset, scheme, &f);
                }
            }
        }
        // Waiter path: park until the in-flight prepare publishes. The
        // span makes single-flight convoys visible in traces: a request
        // that spent 2 s in `prepare.join` was parked behind another
        // requester's pipeline run, not doing work of its own.
        match crate::obs::span("prepare.join", || flight.wait()) {
            Ok(g) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Ok((g, true))
            }
            Err(msg) => Err(anyhow!("{msg}")),
        }
    }

    /// Leader path of [`Self::get_or_prepare`]: run the pipeline, swap
    /// the pending marker for the result, wake the waiters.
    fn run_prepare(
        &self,
        id: &str,
        dataset: &str,
        scheme: &str,
        flight: &Arc<InFlight>,
    ) -> Result<(Arc<PreparedGraph>, bool)> {
        let mut guard = PendingGuard { registry: self, id, flight, armed: true };
        let result = self.prepare(dataset, scheme).map(Arc::new);
        // Collect live (mutable) ids *before* taking the registry lock —
        // the two mutexes are never nested, in either order.
        let pinned = self.live_ids();
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match &result {
            Ok(g) => {
                inner
                    .map
                    .insert(id.to_string(), Slot::Ready { graph: g.clone(), recency: clock });
                self.evict_over_capacity(&mut inner, &pinned);
                self.first_ready.store(true, Ordering::Relaxed);
            }
            Err(_) => {
                inner.map.remove(id);
            }
        }
        drop(inner);
        guard.armed = false;
        flight.publish(
            result
                .as_ref()
                .map(Arc::clone)
                .map_err(|e| format!("{e:#}")),
        );
        result.map(|g| (g, false))
    }

    /// Evict min-recency ready artifacts down to capacity — the only
    /// O(n) scan left in the cache, and it runs at insert time, never on
    /// the query hit path. Pending markers are not evictable, and
    /// neither are `pinned` ids (artifacts with open live-mutation
    /// state: evicting one would fork the registry's view of the graph
    /// from the WAL's).
    fn evict_over_capacity(&self, inner: &mut Inner, pinned: &HashSet<String>) {
        while inner.ready_count() > self.cfg.capacity.max(1) {
            let coldest = inner
                .map
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { recency, .. } if !pinned.contains(k) => {
                        Some((*recency, k.clone()))
                    }
                    _ => None,
                })
                .min()
                .map(|(_, k)| k);
            match coldest {
                Some(k) => {
                    inner.map.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
    }

    /// Snapshot of cached artifacts, hottest last.
    pub fn list(&self) -> Vec<Arc<PreparedGraph>> {
        let inner = self.inner.lock().unwrap();
        let mut rows: Vec<(u64, Arc<PreparedGraph>)> = inner
            .map
            .values()
            .filter_map(|s| match s {
                Slot::Ready { graph, recency } => Some((*recency, graph.clone())),
                Slot::Pending(_) => None,
            })
            .collect();
        rows.sort_by_key(|(r, _)| *r);
        rows.into_iter().map(|(_, g)| g).collect()
    }

    /// Cached (query-ready) artifact count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().ready_count()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pipeline executions so far — the single-flight observability
    /// handle (`tests/batch_equiv.rs` hammers a cold key from 8 threads
    /// and asserts this reads 1).
    pub fn prepares(&self) -> u64 {
        self.prepares.load(Ordering::Relaxed)
    }

    /// Prepare-cache hits (see [`Self::get_or_prepare`]) — exported to
    /// `/metrics` as `boba_registry_hits_total`.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Prepare-cache misses.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// LRU evictions.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Configured LRU capacity.
    pub fn capacity(&self) -> usize {
        self.cfg.capacity
    }

    /// Cache counters as JSON (for `/stats`).
    pub fn stats_json(&self) -> Json {
        Json::obj(vec![
            ("graphs", Json::Num(self.len() as f64)),
            ("capacity", Json::Num(self.cfg.capacity as f64)),
            ("hits", Json::Num(self.hits.load(Ordering::Relaxed) as f64)),
            ("misses", Json::Num(self.misses.load(Ordering::Relaxed) as f64)),
            ("evictions", Json::Num(self.evictions.load(Ordering::Relaxed) as f64)),
            ("prepares", Json::Num(self.prepares.load(Ordering::Relaxed) as f64)),
        ])
    }

    /// Run the Problem-3 pipeline once for `(dataset, scheme)`.
    ///
    /// The pipeline checks the thread-local [`deadline`] between stages:
    /// a leader whose request budget lapses aborts cleanly (waiters get
    /// the error and the key stays retryable) instead of finishing work
    /// nobody is waiting for.
    fn prepare(&self, dataset: &str, scheme: &str) -> Result<PreparedGraph> {
        // Fault point: an armed `prepare-fail` chaos spec fails the
        // pipeline before it starts — the resilience harness uses it to
        // drive the single-flight error path deterministically.
        if crate::obs::chaos::should("prepare-fail") {
            anyhow::bail!("injected fault: prepare-fail");
        }
        self.prepares.fetch_add(1, Ordering::Relaxed);
        let mut prep = PrepReport::default();

        // ── source + batched ingest ───────────────────────────────
        // Generated specs get the paper's randomized-label input model;
        // files are served with the labels they carry. The span (and
        // ingest_ms) covers source acquisition *plus* the streaming
        // assembly: for generated specs the generation + randomization
        // is real request work, and leaving it untimed would leave a
        // hole in the trace the stage sum can't explain.
        let sw = Stopwatch::start();
        let (coo, batches) = crate::obs::span("prepare.ingest", || -> Result<(Coo, usize)> {
            let source = load_source(dataset, self.cfg.seed)
                .with_context(|| format!("ingesting dataset {dataset:?}"))?;
            let (producer, stream) =
                StreamingIngest::from_coo(source, self.cfg.batch, self.cfg.in_flight);
            let out = stream.collect();
            producer.join().ok();
            Ok(out)
        })?;
        prep.ingest_ms = sw.ms();
        prep.batches = batches;
        check_deadline("ingest")?;
        self.build_from_coo(dataset, scheme, coo, 0, prep)
    }

    /// Re-run the post-ingest pipeline (reorder → convert → transpose →
    /// format) on an already-materialized COO, producing an artifact at
    /// `epoch`. This is the compactor's path — it folds the delta
    /// overlay into a merged COO and re-runs BOBA *online*, which is
    /// the paper's amortization claim under churn — and WAL recovery's
    /// (checkpoint or re-ingested source + replay). Counted separately
    /// from [`Self::prepares`] via [`Self::compactions`].
    pub fn rebuild_from_coo(
        &self,
        dataset: &str,
        scheme: &str,
        coo: Coo,
        epoch: u64,
    ) -> Result<PreparedGraph> {
        self.build_from_coo(dataset, scheme, coo, epoch, PrepReport::default())
    }

    /// Shared tail of [`Self::prepare`] and [`Self::rebuild_from_coo`].
    fn build_from_coo(
        &self,
        dataset: &str,
        scheme: &str,
        coo: Coo,
        epoch: u64,
        mut prep: PrepReport,
    ) -> Result<PreparedGraph> {
        // ── reorder (+relabel) ────────────────────────────────────
        let (perm, working) = if scheme == SCHEME_NONE {
            (None, coo)
        } else {
            let reorderer = reorder::by_name(scheme, self.cfg.seed)?;
            let sw = Stopwatch::start();
            let (perm, relabeled) =
                crate::obs::span("prepare.reorder", || reorderer.reorder_relabel(&coo));
            prep.reorder_ms = sw.ms();
            (Some(Arc::new(perm)), relabeled)
        };
        check_deadline("reorder")?;

        // ── convert ───────────────────────────────────────────────
        // The deterministic parallel kernel: prepare is the serving hot
        // path the worker pool + non-atomic counting sort exist for, and
        // its output is bit-identical to the sequential converter, so
        // digests still compare across schemes and thread counts.
        let sw = Stopwatch::start();
        let csr = crate::obs::span("prepare.convert", || convert::coo_to_csr_parallel(&working));
        prep.convert_ms = sw.ms();
        check_deadline("convert")?;

        // ── transpose ─────────────────────────────────────────────
        // Eagerly build the pull operand (`Aᵀ` structure) so PageRank
        // queries never pay a per-call transpose; priced as its own
        // stage in PrepReport and the prepare trace.
        let sw = Stopwatch::start();
        let transpose = crate::obs::span("prepare.transpose", || csr.transposed_structure());
        prep.transpose_ms = sw.ms();
        check_deadline("transpose")?;

        // ── kernel format (optional) ──────────────────────────────
        // Encode the compressed variant and gate it behind the repo's
        // determinism bar right here: a probe SpMV must be bit-
        // identical to spmv_pull before the artifact is published, so
        // a bad encode can never serve a single wrong query.
        let format = match self.cfg.format.as_deref() {
            None => None,
            Some(name) => {
                let sw = Stopwatch::start();
                let enc = crate::obs::span("prepare.format", || {
                    crate::runtime::format::encode(name, &csr)
                })
                .with_context(|| format!("encoding kernel format for {dataset}@{scheme}"))?;
                let x: Vec<f32> =
                    (0..csr.n()).map(|i| ((i % 251) as f32).mul_add(0.25, -31.0)).collect();
                let want = crate::algos::spmv::spmv_pull(&csr, &x);
                let got = enc.spmv(&x);
                anyhow::ensure!(
                    want.len() == got.len()
                        && want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "format {name:?} SpMV diverges bitwise from spmv_pull on \
                     {dataset}@{scheme} — refusing to publish the artifact"
                );
                prep.format_ms = sw.ms();
                Some(Arc::from(enc))
            }
        };

        Ok(PreparedGraph {
            id: Self::id_of(dataset, scheme),
            dataset: dataset.to_string(),
            scheme: scheme.to_string(),
            csr: Arc::new(csr),
            transpose: Arc::new(transpose),
            perm,
            format,
            prep,
            epoch,
            queries: AtomicU64::new(0),
            default_source: OnceLock::new(),
            tc: OnceLock::new(),
        })
    }

    // ── live mutation state ───────────────────────────────────────

    /// The configured WAL directory, if mutations are enabled.
    pub fn wal_dir(&self) -> Option<&Path> {
        self.cfg.wal_dir.as_deref()
    }

    /// The background-compaction trigger threshold (0 = disabled).
    pub fn compact_threshold(&self) -> usize {
        self.cfg.compact_threshold
    }

    /// Open (or return the cached) live-mutation handle for `graph`.
    /// Errors when the registry has no `--wal-dir`. The first open for
    /// a graph writes its recovery meta and replays any WAL already on
    /// disk under its key.
    pub fn live_for(&self, graph: &Arc<PreparedGraph>) -> Result<Arc<LiveGraph>> {
        let Some(dir) = self.cfg.wal_dir.clone() else {
            anyhow::bail!("mutations are disabled: the server was started without --wal-dir");
        };
        let mut live = self.live.lock().unwrap();
        if let Some(l) = live.get(&graph.id) {
            return Ok(l.clone());
        }
        let key = wal::key_for(&graph.id);
        wal::write_meta(&dir, &key, &graph.id, &graph.dataset, &graph.scheme, graph.epoch)?;
        let never = AtomicBool::new(false);
        let report = wal::scan(&dir, &key, &never, true)?;
        let l = LiveGraph::open(&dir, graph.clone(), graph.epoch, report)?;
        live.insert(graph.id.clone(), l.clone());
        Ok(l)
    }

    /// Cached live handle by artifact id (no side effects).
    pub fn live_graph(&self, id: &str) -> Option<Arc<LiveGraph>> {
        self.live.lock().unwrap().get(id).cloned()
    }

    /// Install a recovered live handle (WAL replay path).
    pub fn install_live(&self, l: Arc<LiveGraph>) {
        self.live.lock().unwrap().insert(l.id.clone(), l);
    }

    /// Every open live handle (metrics aggregation).
    pub fn live_list(&self) -> Vec<Arc<LiveGraph>> {
        self.live.lock().unwrap().values().cloned().collect()
    }

    /// Ids with open live-mutation state — pinned against LRU eviction.
    fn live_ids(&self) -> HashSet<String> {
        self.live.lock().unwrap().keys().cloned().collect()
    }

    /// Publish (or republish) a ready artifact under `id` — the
    /// compactor's epoch swap and recovery both land artifacts here
    /// without going through the prepare pipeline. Never evicts: the
    /// published id is live-pinned by construction.
    pub fn publish(&self, id: &str, graph: Arc<PreparedGraph>) {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        inner.map.insert(id.to_string(), Slot::Ready { graph, recency: clock });
        self.first_ready.store(true, Ordering::Relaxed);
    }

    /// Record one completed compaction.
    pub fn note_compaction(&self) {
        self.compactions.fetch_add(1, Ordering::Relaxed);
    }

    /// Completed compactions (`boba_compactions_total`).
    pub fn compactions(&self) -> u64 {
        self.compactions.load(Ordering::Relaxed)
    }

    /// A background compactor thread started.
    pub fn compaction_started(&self) {
        self.active_compactions.fetch_add(1, Ordering::Relaxed);
    }

    /// A background compactor thread finished.
    pub fn compaction_finished(&self) {
        self.active_compactions.fetch_sub(1, Ordering::Relaxed);
    }

    /// Compactor threads currently running.
    pub fn active_compactions(&self) -> u64 {
        self.active_compactions.load(Ordering::Relaxed)
    }

    /// Set the number of graphs whose WALs still need replay — called
    /// synchronously at server start (before the accept loop) so the
    /// very first `/readyz` already reports `recovering`.
    pub fn set_recovering(&self, n: usize) {
        // ordering: SeqCst — the readiness gauge; pairs with the
        // decrements and `/readyz`'s load so readiness flips exactly
        // once all replays observed by this store have finished.
        self.recovering.store(n, Ordering::SeqCst);
    }

    /// One graph finished (or abandoned) replay.
    pub fn dec_recovering(&self) {
        // Saturating: recovery may call this after an early set_recovering(0).
        // ordering: SeqCst (both) — pairs with set_recovering's store
        // and recovering()'s load; see set_recovering.
        let _ = self.recovering.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
            Some(v.saturating_sub(1))
        });
    }

    /// Graphs still replaying their WAL.
    pub fn recovering(&self) -> usize {
        // ordering: SeqCst — pairs with set_recovering/dec_recovering.
        self.recovering.load(Ordering::SeqCst)
    }

    /// Load the original-space COO for `dataset` exactly as the prepare
    /// pipeline would (same seed, same randomization) — WAL recovery's
    /// base when no checkpoint has landed yet.
    pub fn load_base_coo(&self, dataset: &str) -> Result<Coo> {
        load_source(dataset, self.cfg.seed)
    }
}

/// Inter-stage deadline checkpoint for the prepare pipeline: errors
/// when the requesting thread's [`deadline`] has lapsed, naming the
/// stage that just finished.
fn check_deadline(after_stage: &str) -> Result<()> {
    anyhow::ensure!(
        !deadline::expired(),
        "deadline exceeded after prepare {after_stage} stage"
    );
    Ok(())
}

/// Load a dataset spec: a `.mtx`/`.el`/`.bcoo` file path, or a
/// generator spec resolved through [`datasets::resolve`] and randomized
/// (the paper's input model — §5: "input labels are already
/// randomized"). File paths go through the parallel byte-level readers
/// and the `.bcoo` sidecar cache ([`crate::graph::io::load_graph_file`]
/// via [`datasets::resolve_source`]), so re-registering a file after an
/// eviction or restart is a memcpy-speed binary load, not a re-parse.
fn load_source(spec: &str, seed: u64) -> Result<Coo> {
    if datasets::is_file_spec(spec) {
        // File labels are served as-is (resolve_source preserves edge-
        // list IDs: a dense relabel would pre-reorder the baseline).
        return datasets::resolve_source(spec, seed);
    }
    Ok(datasets::resolve_source(spec, seed)?.randomized(seed + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::spmv;

    fn registry(capacity: usize) -> GraphRegistry {
        GraphRegistry::new(RegistryConfig {
            capacity,
            batch: 500,
            in_flight: 2,
            seed: 7,
            ..RegistryConfig::default()
        })
    }

    #[test]
    fn prepare_caches_and_hits() {
        let r = registry(4);
        let (a, cached_a) = r.get_or_prepare("pa:2000:4", "boba").unwrap();
        assert!(!cached_a);
        let (b, cached_b) = r.get_or_prepare("pa:2000:4", "boba").unwrap();
        assert!(cached_b);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(r.len(), 1);
        assert_eq!(a.id, "pa:2000:4@boba");
        assert!(a.perm.is_some());
        assert!(a.prep.batches >= 1);
    }

    #[test]
    fn scheme_none_serves_randomized_labels() {
        let r = registry(4);
        let (g, _) = r.get_or_prepare("pa:1500:4", SCHEME_NONE).unwrap();
        assert!(g.perm.is_none());
        assert_eq!(g.prep.reorder_ms, 0.0);
        // Same dataset under boba is a distinct artifact with the same
        // size and the same label-invariant SpMV digest.
        let (h, _) = r.get_or_prepare("pa:1500:4", "boba").unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(g.m(), h.m());
        let digest = |csr: &Csr| -> f64 {
            let x = vec![1.0f32; csr.n()];
            spmv::spmv_pull(csr, &x).iter().map(|&v| v as f64).sum()
        };
        assert!((digest(&g.csr) - digest(&h.csr)).abs() < 1e-6 * g.m() as f64);
    }

    #[test]
    fn prepare_caches_the_transpose() {
        let r = registry(2);
        let (g, _) = r.get_or_prepare("pa:1500:4", "boba").unwrap();
        assert!(g.prep.transpose_ms >= 0.0);
        assert_eq!(g.transpose.n(), g.n());
        assert_eq!(g.transpose.m(), g.m());
        assert!(g.transpose.vals.is_none(), "structure only — no weight array");
        let full = g.csr.transposed_structure();
        assert_eq!(g.transpose.row_ptr, full.row_ptr);
        assert_eq!(g.transpose.col_idx, full.col_idx);
        let j = g.prep.to_json();
        assert!(j.get("transpose_ms").is_some());
        let total = j.get("total_ms").unwrap().as_f64().unwrap();
        let sum = ["ingest_ms", "reorder_ms", "convert_ms", "transpose_ms", "format_ms"]
            .iter()
            .map(|k| j.get(k).unwrap().as_f64().unwrap())
            .sum::<f64>();
        assert!((total - sum).abs() < 1e-9);
    }

    #[test]
    fn format_variant_is_prepared_and_gated() {
        let r = GraphRegistry::new(RegistryConfig {
            capacity: 2,
            batch: 500,
            in_flight: 2,
            seed: 7,
            format: Some("delta".to_string()),
            ..RegistryConfig::default()
        });
        let (g, _) = r.get_or_prepare("pa:1500:4", "boba").unwrap();
        let f = g.format.as_ref().expect("artifact must carry the delta variant");
        assert_eq!(f.name(), "delta");
        assert_eq!(f.m(), g.m());
        // The delta narrow rule makes ≤ 4 B/edge an invariant.
        assert!(f.bytes_per_edge() <= 4.0 + 1e-12, "got {}", f.bytes_per_edge());
        assert!(g.prep.format_ms > 0.0, "format stage must be priced");
        let j = g.to_json();
        assert_eq!(j.get("format").and_then(|v| v.as_str()), Some("delta"));
        assert!(j.get("format_bytes_per_edge").is_some());

        // Unknown names fail prepare, not serve time.
        let bad = GraphRegistry::new(RegistryConfig {
            format: Some("bitmap".to_string()),
            ..RegistryConfig::default()
        });
        assert!(bad.get_or_prepare("pa:1000:4", "boba").is_err());
    }

    #[test]
    fn lru_evicts_coldest() {
        let r = registry(2);
        r.get_or_prepare("pa:1000:4", "boba").unwrap();
        r.get_or_prepare("pa:1100:4", "boba").unwrap();
        // Touch the first so the second becomes coldest.
        assert!(r.get("pa:1000:4@boba").is_some());
        r.get_or_prepare("pa:1200:4", "boba").unwrap();
        assert_eq!(r.len(), 2);
        assert!(r.get("pa:1100:4@boba").is_none(), "coldest entry evicted");
        assert!(r.get("pa:1000:4@boba").is_some());
        assert!(r.get("pa:1200:4@boba").is_some());
    }

    #[test]
    fn live_pinned_artifacts_survive_eviction() {
        let dir = std::env::temp_dir()
            .join(format!("boba-reg-pin-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = GraphRegistry::new(RegistryConfig {
            capacity: 1,
            batch: 500,
            in_flight: 2,
            seed: 7,
            wal_dir: Some(dir.clone()),
            ..RegistryConfig::default()
        });
        let (g1, _) = r.get_or_prepare("pa:1000:4", "boba").unwrap();
        let _live = r.live_for(&g1).unwrap();
        // Capacity 1 + a second prepare would normally evict g1 (it is
        // the coldest) — the open live handle pins it instead.
        r.get_or_prepare("pa:1100:4", "boba").unwrap();
        assert!(
            r.get("pa:1000:4@boba").is_some(),
            "an artifact with live-mutation state must never be evicted"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mutations_disabled_without_wal_dir() {
        let r = registry(2);
        let (g, _) = r.get_or_prepare("pa:800:4", "boba").unwrap();
        let err = r.live_for(&g).unwrap_err().to_string();
        assert!(err.contains("--wal-dir"), "{err}");
        assert_eq!(g.epoch, 0, "fresh prepares start at epoch 0");
    }

    #[test]
    fn unknown_specs_error() {
        let r = registry(2);
        assert!(r.get_or_prepare("nope:13", "boba").is_err());
        assert!(r.get_or_prepare("pa:1000:4", "definitely-not-a-scheme").is_err());
        assert_eq!(r.len(), 0, "failed prepares cache nothing");
        // A failed prepare clears its in-flight marker: the key stays
        // retryable and a later valid request succeeds.
        assert!(r.get_or_prepare("pa:1000:4", "boba").is_ok());
    }

    #[test]
    fn counters_track_prepare_outcomes() {
        let r = registry(4);
        r.get_or_prepare("pa:1000:4", "boba").unwrap();
        r.get_or_prepare("pa:1000:4", "boba").unwrap();
        r.get_or_prepare("pa:1000:4", "boba").unwrap();
        assert_eq!(r.prepares(), 1, "one pipeline run");
        let stats = r.stats_json();
        assert_eq!(stats.get("misses").unwrap().as_u64(), Some(1));
        assert_eq!(stats.get("hits").unwrap().as_u64(), Some(2));
        assert_eq!(stats.get("prepares").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn concurrent_cold_requesters_single_flight() {
        let r = std::sync::Arc::new(registry(4));
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(4));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let r = r.clone();
            let b = barrier.clone();
            handles.push(std::thread::spawn(move || {
                b.wait();
                r.get_or_prepare("pa:2500:4", "boba").unwrap()
            }));
        }
        let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(r.prepares(), 1, "the pipeline must run exactly once");
        assert_eq!(outs.iter().filter(|(_, cached)| !cached).count(), 1, "one leader");
        for (g, _) in &outs {
            assert!(Arc::ptr_eq(g, &outs[0].0), "all requesters share one artifact");
        }
    }

    #[test]
    fn tc_view_counts_triangles_like_pipeline() {
        use crate::algos::tc;
        use crate::coordinator::pipeline::{App, Pipeline, ReorderStage};
        let r = registry(2);
        let (g, _) = r.get_or_prepare("pa:1200:4", "boba").unwrap();
        let view = g.tc_view();
        let served = tc::triangle_count_ranked(&view.dag, &view.rank);
        // Reference: the offline pipeline on the same randomized COO.
        let coo = datasets::resolve("pa:1200:4", 7).unwrap().randomized(8);
        let report = Pipeline::new(App::Tc).run(&coo, &ReorderStage::None);
        assert_eq!(served as f64, report.digest);
    }

    #[test]
    fn default_source_is_stable_and_in_range() {
        let r = registry(2);
        let (g, _) = r.get_or_prepare("pa:900:4", "degree").unwrap();
        let s = g.default_source();
        assert_eq!(s, g.default_source());
        assert!((s as usize) < g.n());
    }

    #[test]
    fn mid_first_prepare_reflects_pending_state() {
        let r = registry(2);
        assert!(!r.mid_first_prepare(), "an idle empty registry is ready");
        r.inner
            .lock()
            .unwrap()
            .map
            .insert("x@y".to_string(), Slot::Pending(Arc::new(InFlight::new())));
        assert!(r.mid_first_prepare(), "a cold first prepare degrades readiness");
        r.inner.lock().unwrap().map.remove("x@y");
        r.get_or_prepare("pa:800:4", "boba").unwrap();
        // Once anything is servable, later prepares don't degrade.
        r.inner
            .lock()
            .unwrap()
            .map
            .insert("x@y".to_string(), Slot::Pending(Arc::new(InFlight::new())));
        assert!(!r.mid_first_prepare());
        r.inner.lock().unwrap().map.remove("x@y");
    }

    #[test]
    fn expired_deadline_aborts_prepare_between_stages() {
        let r = registry(2);
        let _d = deadline::scope(Some(std::time::Instant::now()));
        let err = r.get_or_prepare("pa:900:4", "boba").unwrap_err();
        assert!(
            format!("{err:#}").contains("deadline exceeded after prepare"),
            "{err:#}"
        );
        drop(_d);
        // The key stays retryable once the budget pressure is gone.
        assert!(r.get_or_prepare("pa:900:4", "boba").is_ok());
    }

    #[test]
    fn waiter_detaches_on_deadline_without_touching_the_leader() {
        let flight = InFlight::new();
        let _d = deadline::scope(Some(std::time::Instant::now() + Duration::from_millis(20)));
        let sw = std::time::Instant::now();
        let out = flight.wait();
        assert!(out.unwrap_err().contains("deadline"), "waiter detaches with its own error");
        assert!(sw.elapsed() < Duration::from_secs(5), "detach is prompt, not a hang");
        drop(_d);
        // The flight is unpoisoned: a later publish reaches new waiters.
        flight.publish(Err("real outcome".to_string()));
        assert_eq!(flight.wait().unwrap_err(), "real outcome");
    }

    #[test]
    fn bcoo_file_specs_load_binary() {
        use crate::graph::io::bcoo;
        let g = Coo::new(4, vec![0, 1, 2, 3], vec![1, 2, 3, 0]);
        let path = std::env::temp_dir()
            .join(format!("boba_registry_{}.bcoo", std::process::id()));
        bcoo::write_bcoo(&g, &path).unwrap();
        let r = registry(2);
        let (p, _) = r.get_or_prepare(path.to_str().unwrap(), SCHEME_NONE).unwrap();
        assert_eq!(p.m(), 4);
        assert_eq!(p.n(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_specs_load_edge_lists() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("boba_registry_{}.el", std::process::id()));
        std::fs::write(&path, "0 1\n1 2\n2 0\n").unwrap();
        let r = registry(2);
        let (g, _) = r
            .get_or_prepare(path.to_str().unwrap(), SCHEME_NONE)
            .unwrap();
        assert_eq!(g.m(), 3);
        std::fs::remove_file(&path).ok();
    }
}
