//! Request coalescing: batch concurrent queries against the same
//! prepared artifact into one multi-RHS kernel pass.
//!
//! Faldu et al.'s amortization argument made explicit at query
//! granularity: the registry already amortizes the *reorder* cost
//! across queries; the coalescer amortizes the *edge-stream* cost
//! (`row_ptr`/`col_idx` — pure bandwidth, the part reordering cannot
//! compress) across concurrent queries by answering k parked SpMV
//! queries with one [`crate::algos::spmm::spmm_pull_parallel`] call and
//! s parked SSSP queries with one
//! [`crate::algos::sssp::sssp_frontier_multi`] scan.
//!
//! Mechanics: one batching group per `(artifact instance, query kind)`
//! — keyed by the `Arc<PreparedGraph>` address, not the registry id, so
//! queries that resolved different generations of a re-prepared
//! artifact can never share a batch (an id-keyed group could hand a
//! follower's label-dependent query to a leader holding a stale
//! generation with different vertex labels). The key cannot alias: a
//! group member keeps its artifact alive for the whole submit call, so
//! an address is only reused once the old group is empty. Groups whose
//! artifact went idle are pruned from the map on the way out, so the
//! map tracks live artifacts, not everything ever served. The first
//! query to arrive becomes the batch *leader*: it waits up to
//! `window` (`--batch-window-us`) for companions — or returns
//! immediately with whatever is already queued when the window is zero
//! — then drains up to `max_batch` requests and executes them in one
//! kernel pass. Queries arriving while that batch is in flight park on
//! the group's condvar and form the next batch, so under load batches
//! widen naturally even with a zero window (the in-flight execution
//! *is* the window). Batching never changes answers: the batched
//! kernels are bit-identical to their one-query forms, so a response is
//! the same whether it was coalesced or not — the serve path stays
//! deterministic at every batch width.
//!
//! Trade-off: a non-zero window adds up to `window` of latency to the
//! *first* query of a batch in exchange for width (≈ k× edge-stream
//! amortization); `window = 0` (the default) only coalesces queries
//! that are already queued and adds no latency. `/stats` exposes the
//! realized batch-width histograms so the trade can be observed live.

use crate::algos::{spmm, sssp};
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::json::Json;
use super::registry::PreparedGraph;
use crate::util::deadline;
use crate::util::prng::Xoshiro256;

/// Coalescer tuning (CLI flags map 1:1 onto these fields).
#[derive(Clone, Copy, Debug)]
pub struct CoalesceConfig {
    /// How long a batch leader waits for companion queries before
    /// executing. Zero (the default) coalesces only already-queued
    /// queries — no added latency.
    pub window: Duration,
    /// Maximum queries per kernel pass (clamped to
    /// [`spmm::MAX_RHS`]).
    pub max_batch: usize,
}

impl Default for CoalesceConfig {
    fn default() -> Self {
        Self { window: Duration::ZERO, max_batch: 8 }
    }
}

/// A coalescable query (the non-coalescable kinds — PageRank, TC — take
/// the direct path in the router).
#[derive(Clone, Copy, Debug)]
pub enum BatchQuery {
    /// One SpMV right-hand side: `None` = the all-ones vector (the
    /// label-invariant digest query), `Some(seed)` = the deterministic
    /// pseudo-random vector [`rhs_vector`] builds.
    Spmv {
        /// RHS seed (`None` = ones).
        seed: Option<u64>,
    },
    /// One SSSP source (already validated against `n` by the caller).
    Sssp {
        /// Source vertex.
        source: u32,
    },
}

/// The per-query answer a batch execution produces.
#[derive(Clone, Copy, Debug)]
pub enum BatchOut {
    /// SpMV: sum of the output vector (f64, label-invariant for ones).
    Spmv {
        /// Σ y as f64.
        digest: f64,
    },
    /// SSSP: sum of finite distances + reached count.
    Sssp {
        /// Σ finite distances as f64.
        digest: f64,
        /// Vertices with finite distance.
        reached: usize,
    },
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Kind {
    Spmv,
    Sssp,
}

impl BatchQuery {
    fn kind(&self) -> Kind {
        match self {
            BatchQuery::Spmv { .. } => Kind::Spmv,
            BatchQuery::Sssp { .. } => Kind::Sssp,
        }
    }
}

/// Build the RHS vector for one SpMV query: all-ones without a seed
/// (digest = m on unweighted graphs, the smoke tests' invariant), a
/// deterministic seeded pseudo-random vector otherwise — so a coalesced
/// batch of seeded queries is a genuine multi-RHS block, not k copies
/// of one vector.
pub fn rhs_vector(n: usize, seed: Option<u64>) -> Vec<f32> {
    match seed {
        None => vec![1.0f32; n],
        Some(s) => {
            let mut rng = Xoshiro256::new(s);
            (0..n).map(|_| rng.next_f32()).collect()
        }
    }
}

/// Execute one SpMV tile (≤ [`spmm::MAX_RHS`] right-hand sides) in a
/// single [`spmm::spmm_pull_parallel`] pass; returns one digest per
/// query. Shared by the coalescer leader and the `/query/batch`
/// endpoint so both price exactly one edge-stream per tile.
pub fn run_spmv_tile(graph: &PreparedGraph, seeds: &[Option<u64>]) -> Vec<f64> {
    let k = seeds.len();
    assert!((1..=spmm::MAX_RHS).contains(&k), "tile width {k}");
    let n = graph.csr.n();
    let mut x = Vec::with_capacity(k * n);
    for s in seeds {
        x.extend(rhs_vector(n, *s));
    }
    let y = crate::obs::span("kernel.spmv", || spmm::spmm_pull_parallel(&graph.csr, &x, k));
    (0..k)
        .map(|j| spmm::column(&y, n, j).iter().map(|&v| v as f64).sum())
        .collect()
}

/// Execute one SSSP tile (≤ [`sssp::MAX_SOURCES`] sources) in a single
/// [`sssp::sssp_frontier_multi`] scan; returns `(digest, reached)` per
/// source.
pub fn run_sssp_tile(graph: &PreparedGraph, sources: &[u32]) -> Vec<(f64, usize)> {
    let s = sources.len();
    assert!((1..=sssp::MAX_SOURCES).contains(&s), "tile width {s}");
    let n = graph.csr.n();
    let d = crate::obs::span("kernel.sssp", || sssp::sssp_frontier_multi(&graph.csr, sources));
    (0..s)
        .map(|i| {
            let col = &d[i * n..(i + 1) * n];
            let digest: f64 = col.iter().filter(|v| v.is_finite()).map(|&v| v as f64).sum();
            let reached = col.iter().filter(|v| v.is_finite()).count();
            (digest, reached)
        })
        .collect()
}

fn execute_batch(graph: &PreparedGraph, batch: &[(u64, BatchQuery)]) -> Vec<BatchOut> {
    // Groups are homogeneous by construction (keyed on Kind).
    match batch[0].1 {
        BatchQuery::Spmv { .. } => {
            let seeds: Vec<Option<u64>> = batch
                .iter()
                .map(|(_, q)| match q {
                    BatchQuery::Spmv { seed } => *seed,
                    // lint: allow(panic-path): structurally dead —
                    // groups are keyed on Kind, so a mixed group is a
                    // coalescer bug, unreachable from request data.
                    _ => unreachable!("mixed kinds in one group"),
                })
                .collect();
            run_spmv_tile(graph, &seeds)
                .into_iter()
                .map(|digest| BatchOut::Spmv { digest })
                .collect()
        }
        BatchQuery::Sssp { .. } => {
            let sources: Vec<u32> = batch
                .iter()
                .map(|(_, q)| match q {
                    BatchQuery::Sssp { source } => *source,
                    // lint: allow(panic-path): structurally dead —
                    // groups are keyed on Kind, so a mixed group is a
                    // coalescer bug, unreachable from request data.
                    _ => unreachable!("mixed kinds in one group"),
                })
                .collect();
            run_sssp_tile(graph, &sources)
                .into_iter()
                .map(|(digest, reached)| BatchOut::Sssp { digest, reached })
                .collect()
        }
    }
}

/// Realized batch-width accounting for one query kind (rendered as the
/// `/stats` width histogram).
#[derive(Debug, Default)]
pub struct BatchWidths {
    counts: [AtomicU64; spmm::MAX_RHS],
    batches: AtomicU64,
    queries: AtomicU64,
}

impl BatchWidths {
    /// Record one executed batch of `width` queries.
    pub fn record(&self, width: usize) {
        debug_assert!((1..=spmm::MAX_RHS).contains(&width));
        self.counts[width.clamp(1, spmm::MAX_RHS) - 1].fetch_add(1, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.queries.fetch_add(width as u64, Ordering::Relaxed);
    }

    /// Batches executed.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Queries answered across all batches.
    pub fn queries(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    /// Snapshot of the per-width batch counts: index `i` holds the
    /// number of batches executed at width `i + 1`. Feeds the
    /// `boba_coalesce_batch_width` histogram on `/metrics`.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// JSON snapshot: totals, mean width, and the non-empty width
    /// buckets.
    pub fn to_json(&self) -> Json {
        let batches = self.batches();
        let queries = self.queries();
        let widths: Vec<(String, Json)> = self
            .counts
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then(|| ((i + 1).to_string(), Json::Num(c as f64)))
            })
            .collect();
        Json::obj(vec![
            ("batches", Json::Num(batches as f64)),
            ("queries", Json::Num(queries as f64)),
            (
                "mean_width",
                Json::Num(if batches == 0 { 0.0 } else { queries as f64 / batches as f64 }),
            ),
            ("widths", Json::Obj(widths)),
        ])
    }
}

struct GroupState {
    /// Requests not yet claimed by a batch, FIFO.
    queue: Vec<(u64, BatchQuery)>,
    /// Finished answers keyed by ticket (`Err` = execution panicked).
    results: HashMap<u64, std::result::Result<(BatchOut, usize), String>>,
    /// A leader is currently forming or executing a batch.
    leader: bool,
    next_ticket: u64,
    shutdown: bool,
}

struct Group {
    state: Mutex<GroupState>,
    cv: Condvar,
}

impl Group {
    fn new() -> Group {
        Group {
            state: Mutex::new(GroupState {
                queue: Vec::new(),
                results: HashMap::new(),
                leader: false,
                next_ticket: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Group key: the artifact's allocation address plus the query kind.
/// Address, not id — see the module docs for why (stale-generation
/// isolation) and why it cannot alias (members pin the allocation).
type GroupKey = (usize, Kind);

/// The per-`(artifact, kind)` query coalescer (see the module docs for
/// the batching protocol).
pub struct Coalescer {
    cfg: CoalesceConfig,
    groups: Mutex<HashMap<GroupKey, Arc<Group>>>,
    down: AtomicBool,
    spmv_widths: BatchWidths,
    sssp_widths: BatchWidths,
}

impl Coalescer {
    /// New coalescer (`max_batch` clamped to `1..=`[`spmm::MAX_RHS`]).
    pub fn new(mut cfg: CoalesceConfig) -> Coalescer {
        cfg.max_batch = cfg.max_batch.clamp(1, spmm::MAX_RHS);
        Coalescer {
            cfg,
            groups: Mutex::new(HashMap::new()),
            down: AtomicBool::new(false),
            spmv_widths: BatchWidths::default(),
            sssp_widths: BatchWidths::default(),
        }
    }

    fn widths(&self, kind: Kind) -> &BatchWidths {
        match kind {
            Kind::Spmv => &self.spmv_widths,
            Kind::Sssp => &self.sssp_widths,
        }
    }

    /// Batch-width accounting for the SpMV kind (also fed by the
    /// `/query/batch` endpoint's explicit tiles).
    pub fn spmv_widths(&self) -> &BatchWidths {
        &self.spmv_widths
    }

    /// Batch-width accounting for the SSSP kind.
    pub fn sssp_widths(&self) -> &BatchWidths {
        &self.sssp_widths
    }

    /// Submit one query; blocks until the batch containing it has
    /// executed. Returns the answer and the width of the batch it rode
    /// in. Errors if the coalescer is shut down while the query is
    /// parked (or before it enqueues).
    pub fn submit(&self, graph: &Arc<PreparedGraph>, q: BatchQuery) -> Result<(BatchOut, usize)> {
        ensure!(!self.down.load(Ordering::Relaxed), "coalescer is shut down");
        let kind = q.kind();
        let key: GroupKey = (Arc::as_ptr(graph) as usize, kind);
        let group = {
            let mut gs = self.groups.lock().unwrap();
            gs.entry(key).or_insert_with(|| Arc::new(Group::new())).clone()
        };
        let mut st = group.state.lock().unwrap();
        // Re-check the global flag under the group lock: if shutdown()
        // collected the group map before our group was registered, its
        // `down` store is visible here (the groups-map mutex orders the
        // insert against the collection), so we can never park in a
        // group shutdown will not visit.
        if st.shutdown || self.down.load(Ordering::Relaxed) {
            bail!("coalescer is shut down");
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push((ticket, q));
        // A leader parked in its window may now be full — let it see us.
        group.cv.notify_all();
        loop {
            if let Some(out) = st.results.remove(&ticket) {
                // Last one out turns off the light: an idle group (no
                // queued work, no pending answers, no leader) is removed
                // from the map so evicted/re-prepared artifacts do not
                // leak one group per generation.
                let idle = st.queue.is_empty() && st.results.is_empty() && !st.leader;
                drop(st);
                if idle {
                    self.prune(&key, &group);
                }
                return out.map_err(|m| anyhow::anyhow!("{m}"));
            }
            if st.shutdown {
                st.queue.retain(|(t, _)| *t != ticket);
                group.cv.notify_all();
                bail!("coalescer shut down with the query parked");
            }
            let queued = st.queue.iter().any(|(t, _)| *t == ticket);
            if !queued || st.leader {
                // Either an executing leader owns our request, or a
                // forming batch will take it — park until woken. A
                // ticket still *queued* is withdrawable: if the request
                // deadline lapses before any leader claims it, pull it
                // back and answer the timeout. Once claimed (no longer
                // queued) the kernel is running on our behalf and we
                // park unconditionally for the result.
                if queued {
                    if let Some(budget) = deadline::remaining() {
                        if budget.is_zero() {
                            st.queue.retain(|(t, _)| *t != ticket);
                            group.cv.notify_all();
                            bail!("deadline exceeded while parked for coalescing");
                        }
                        let (g, _) = group
                            .cv
                            .wait_timeout(st, budget.min(Duration::from_millis(250)))
                            .unwrap();
                        st = g;
                        continue;
                    }
                }
                st = group.cv.wait(st).unwrap();
                continue;
            }
            // Become the leader: optionally hold the window open.
            st.leader = true;
            if !self.cfg.window.is_zero() {
                let close = Instant::now() + self.cfg.window;
                while st.queue.len() < self.cfg.max_batch && !st.shutdown {
                    let now = Instant::now();
                    if now >= close {
                        break;
                    }
                    // A leader whose own request deadline lapses stops
                    // holding the window open and executes what is
                    // already queued (followers still get answers; the
                    // leader's own reply becomes a 504 in the router).
                    if deadline::expired() {
                        break;
                    }
                    let mut wait = close - now;
                    if let Some(rem) = deadline::remaining() {
                        wait = wait.min(rem.max(Duration::from_millis(1)));
                    }
                    let (g, _) = group.cv.wait_timeout(st, wait).unwrap();
                    st = g;
                }
            }
            if st.shutdown {
                st.leader = false;
                st.queue.retain(|(t, _)| *t != ticket);
                group.cv.notify_all();
                bail!("coalescer shut down while forming a batch");
            }
            let take = st.queue.len().min(self.cfg.max_batch);
            let batch: Vec<(u64, BatchQuery)> = st.queue.drain(..take).collect();
            drop(st);
            let width = batch.len();
            self.widths(kind).record(width);
            // Unwind-safe: a panicking kernel must not leave followers
            // parked forever — they get an error result instead. The
            // span lands in the *leader's* trace (the kernel ran once,
            // on this thread); followers' traces show the same interval
            // as coalesce wait.
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                crate::obs::span("coalesce.exec", || execute_batch(graph, &batch))
            }));
            let mut st2 = group.state.lock().unwrap();
            st2.leader = false;
            match outcome {
                Ok(outs) => {
                    for ((t, _), out) in batch.iter().zip(outs) {
                        st2.results.insert(*t, Ok((out, width)));
                    }
                }
                Err(_) => {
                    for (t, _) in &batch {
                        st2.results.insert(*t, Err("batch execution panicked".to_string()));
                    }
                }
            }
            group.cv.notify_all();
            st = st2;
            // Loop back: our own answer is in the results map now (our
            // ticket rode this batch unless we arrived > max_batch deep,
            // in which case we queue for the next one).
        }
    }

    /// Remove `group` from the map if it is still the mapped entry for
    /// `key` and is (re-checked under both locks, groups before state —
    /// the crate-wide lock order) still idle. Losing the race to a new
    /// arrival is fine: a thread that fetched the group Arc just before
    /// the removal simply runs its batch in the detached group — every
    /// member of a group can lead, so nothing can park unserved; only
    /// cross-request coalescing with later arrivals is forgone.
    fn prune(&self, key: &GroupKey, group: &Arc<Group>) {
        let mut gs = self.groups.lock().unwrap();
        let mapped = gs.get(key).map_or(false, |g| Arc::ptr_eq(g, group));
        if mapped {
            let idle = {
                let st = group.state.lock().unwrap();
                st.queue.is_empty() && st.results.is_empty() && !st.leader
            };
            if idle {
                gs.remove(key);
            }
        }
    }

    /// Shut down: every parked waiter (including leaders holding a
    /// window open) is released with an error, and new submissions are
    /// refused. Idempotent. A group detached by a racing [`Self::prune`]
    /// is not notified, but detached groups cannot park past their
    /// window (every member can lead and the `down` flag refuses new
    /// work), so shutdown is delayed by at most one window.
    pub fn shutdown(&self) {
        self.down.store(true, Ordering::Relaxed);
        let groups: Vec<Arc<Group>> = self.groups.lock().unwrap().values().cloned().collect();
        for g in groups {
            let mut st = g.state.lock().unwrap();
            st.shutdown = true;
            g.cv.notify_all();
        }
    }

    /// Live batching groups (pruning observability: idle groups are
    /// removed, so this tracks artifacts with in-flight queries, not
    /// everything ever served).
    pub fn group_count(&self) -> usize {
        self.groups.lock().unwrap().len()
    }

    /// `/stats` snapshot: config + live group count + per-kind
    /// batch-width histograms.
    pub fn stats_json(&self) -> Json {
        Json::obj(vec![
            ("window_us", Json::Num(self.cfg.window.as_micros() as f64)),
            ("max_batch", Json::Num(self.cfg.max_batch as f64)),
            ("groups", Json::Num(self.group_count() as f64)),
            ("spmv", self.spmv_widths.to_json()),
            ("sssp", self.sssp_widths.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::spmv;
    use crate::server::registry::{GraphRegistry, RegistryConfig};

    fn prepared() -> Arc<PreparedGraph> {
        let r = GraphRegistry::new(RegistryConfig {
            capacity: 2,
            batch: 1000,
            in_flight: 2,
            seed: 3,
            ..RegistryConfig::default()
        });
        r.get_or_prepare("pa:2000:4", "none").unwrap().0
    }

    #[test]
    fn coalesced_answers_equal_direct_kernels() {
        let g = prepared();
        let co = Arc::new(Coalescer::new(CoalesceConfig {
            window: Duration::from_millis(50),
            max_batch: 8,
        }));
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let co = co.clone();
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                let seed = if i == 0 { None } else { Some(i) };
                (seed, co.submit(&g, BatchQuery::Spmv { seed }).unwrap())
            }));
        }
        for h in handles {
            let (seed, (out, width)) = h.join().unwrap();
            let BatchOut::Spmv { digest } = out else { panic!("kind") };
            let x = rhs_vector(g.csr.n(), seed);
            let want: f64 = spmv::spmv_pull(&g.csr, &x).iter().map(|&v| v as f64).sum();
            assert_eq!(digest, want, "coalescing must not change answers (seed {seed:?})");
            assert!((1..=8).contains(&width));
        }
        assert_eq!(co.spmv_widths().queries(), 6);
        assert!(co.spmv_widths().batches() >= 1);
    }

    #[test]
    fn zero_window_executes_immediately_and_prunes_idle_groups() {
        let g = prepared();
        let co = Coalescer::new(CoalesceConfig::default());
        let (out, width) = co.submit(&g, BatchQuery::Sssp { source: 0 }).unwrap();
        let BatchOut::Sssp { digest, reached } = out else { panic!("kind") };
        let d = crate::algos::sssp::sssp_frontier(&g.csr, 0);
        let want: f64 = d.iter().filter(|v| v.is_finite()).map(|&v| v as f64).sum();
        assert_eq!(digest, want);
        assert_eq!(reached, d.iter().filter(|v| v.is_finite()).count());
        assert_eq!(width, 1);
        // The group went idle with the last member and was pruned.
        assert_eq!(co.group_count(), 0, "idle groups must not accumulate");
        co.submit(&g, BatchQuery::Spmv { seed: None }).unwrap();
        assert_eq!(co.group_count(), 0);
    }

    #[test]
    fn distinct_artifact_generations_never_share_a_batch() {
        // Two generations of the same registry id (different registry
        // seeds ⇒ different randomized labelings). Groups are keyed by
        // artifact instance, so concurrent label-dependent queries must
        // each be answered against the generation they resolved — an
        // id-keyed group would hand one of them to a leader holding the
        // other generation.
        let generation = |seed: u64| {
            let r = GraphRegistry::new(RegistryConfig {
                capacity: 2,
                batch: 1000,
                in_flight: 2,
                seed,
                ..RegistryConfig::default()
            });
            r.get_or_prepare("pa:2000:4", "none").unwrap().0
        };
        let a = generation(3);
        let b = generation(4);
        assert_eq!(a.id, b.id, "same registry id, different generations");
        let co = Arc::new(Coalescer::new(CoalesceConfig {
            window: Duration::from_millis(80),
            max_batch: 16,
        }));
        let mut handles = Vec::new();
        for g in [a.clone(), b.clone()] {
            let co = co.clone();
            handles.push(std::thread::spawn(move || {
                (g.clone(), co.submit(&g, BatchQuery::Spmv { seed: Some(9) }).unwrap())
            }));
        }
        for h in handles {
            let (g, (out, _width)) = h.join().unwrap();
            let BatchOut::Spmv { digest } = out else { panic!("kind") };
            let x = rhs_vector(g.csr.n(), Some(9));
            let want: f64 = spmv::spmv_pull(&g.csr, &x).iter().map(|&v| v as f64).sum();
            assert_eq!(
                digest, want,
                "every query must be answered against its own artifact generation"
            );
        }
    }

    #[test]
    fn shutdown_releases_parked_waiters() {
        let g = prepared();
        // A huge window so the leader (and followers) genuinely park.
        let co = Arc::new(Coalescer::new(CoalesceConfig {
            window: Duration::from_secs(60),
            max_batch: 16,
        }));
        let mut handles = Vec::new();
        for i in 0..4u64 {
            let co = co.clone();
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                co.submit(&g, BatchQuery::Spmv { seed: Some(i) })
            }));
        }
        std::thread::sleep(Duration::from_millis(100));
        co.shutdown();
        for h in handles {
            assert!(h.join().unwrap().is_err(), "parked waiters must be released with an error");
        }
        // Post-shutdown submissions are refused outright.
        assert!(co.submit(&g, BatchQuery::Spmv { seed: None }).is_err());
    }

    #[test]
    fn expired_deadline_withdraws_a_still_queued_follower() {
        let g = prepared();
        let co = Arc::new(Coalescer::new(CoalesceConfig {
            window: Duration::from_secs(60),
            max_batch: 16,
        }));
        let (co2, g2) = (co.clone(), g.clone());
        let leader = std::thread::spawn(move || co2.submit(&g2, BatchQuery::Spmv { seed: None }));
        // Wait until the spawned thread genuinely holds the window open.
        loop {
            let parked = {
                let gs = co.groups.lock().unwrap();
                gs.values().any(|gr| gr.state.lock().unwrap().leader)
            };
            if parked {
                break;
            }
            std::thread::yield_now();
        }
        // A follower whose budget is already spent withdraws its queued
        // ticket promptly instead of parking for the full window.
        let d = deadline::scope(Some(Instant::now()));
        let err = co.submit(&g, BatchQuery::Spmv { seed: Some(7) }).unwrap_err();
        assert!(format!("{err:#}").contains("deadline"), "got {err:#}");
        drop(d);
        // The group is unharmed: the leader still gets released cleanly.
        co.shutdown();
        assert!(leader.join().unwrap().is_err());
    }
}
