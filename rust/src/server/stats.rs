//! Lock-free per-endpoint latency statistics: power-of-two bucketed
//! histograms over microseconds, recorded by worker threads and read by
//! `GET /stats` — the service-side analogue of the offline bench
//! harness's median/MAD summaries.

use crate::util::human;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::json::Json;

/// Number of log2 buckets: bucket `i` counts samples in
/// `[2^(i-1), 2^i)` µs (bucket 0 is `< 1 µs`), so the top bucket covers
/// latencies up to ~2^42 µs ≈ 50 days — effectively unbounded.
const BUCKETS: usize = 43;

/// A concurrent log2 latency histogram (microsecond domain).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// New empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    /// Upper bound (µs) of bucket `i` — the value reported for samples
    /// that landed there.
    fn bucket_upper_us(i: usize) -> u64 {
        1u64 << i
    }

    /// Record one sample.
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Record one sample given in microseconds.
    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e3
        }
    }

    /// Maximum latency in milliseconds.
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Latency quantile in milliseconds, as the upper bound of the
    /// bucket where the cumulative count crosses `q` (0 when empty).
    /// Resolution is a factor of two — plenty for p50/p99 dashboards.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return Self::bucket_upper_us(i) as f64 / 1e3;
            }
        }
        self.max_ms()
    }

    /// JSON snapshot (count/mean/p50/p99/max).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_ms", Json::Num(self.mean_ms())),
            ("p50_ms", Json::Num(self.quantile_ms(0.50))),
            ("p99_ms", Json::Num(self.quantile_ms(0.99))),
            ("max_ms", Json::Num(self.max_ms())),
        ])
    }
}

/// The service's request endpoints (stats slots).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// `POST /graphs` — ingest + prepare.
    Ingest,
    /// `GET /graphs`.
    List,
    /// `POST /graphs/{id}/spmv`.
    Spmv,
    /// `POST /graphs/{id}/pagerank`.
    Pagerank,
    /// `POST /graphs/{id}/sssp`.
    Sssp,
    /// `POST /graphs/{id}/tc`.
    Tc,
    /// `POST /query/batch` — heterogeneous query arrays.
    Batch,
    /// `GET /healthz`.
    Healthz,
    /// `GET /stats`.
    Stats,
}

impl Endpoint {
    /// All endpoints, display order.
    pub const ALL: [Endpoint; 9] = [
        Endpoint::Ingest,
        Endpoint::List,
        Endpoint::Spmv,
        Endpoint::Pagerank,
        Endpoint::Sssp,
        Endpoint::Tc,
        Endpoint::Batch,
        Endpoint::Healthz,
        Endpoint::Stats,
    ];

    /// Stable name used in /stats keys.
    pub fn name(self) -> &'static str {
        match self {
            Endpoint::Ingest => "ingest",
            Endpoint::List => "list",
            Endpoint::Spmv => "spmv",
            Endpoint::Pagerank => "pagerank",
            Endpoint::Sssp => "sssp",
            Endpoint::Tc => "tc",
            Endpoint::Batch => "batch",
            Endpoint::Healthz => "healthz",
            Endpoint::Stats => "stats",
        }
    }

    /// Query endpoint from its URL segment.
    pub fn query_from(seg: &str) -> Option<Endpoint> {
        match seg {
            "spmv" => Some(Endpoint::Spmv),
            "pagerank" | "pr" => Some(Endpoint::Pagerank),
            "sssp" => Some(Endpoint::Sssp),
            "tc" => Some(Endpoint::Tc),
            _ => None,
        }
    }
}

/// Aggregated per-endpoint stats for one server instance.
#[derive(Debug)]
pub struct ServerStats {
    slots: [(Histogram, AtomicU64); 9], // (latencies, error count)
    started: std::time::Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerStats {
    /// Fresh stats (uptime starts now).
    pub fn new() -> ServerStats {
        ServerStats {
            slots: std::array::from_fn(|_| (Histogram::new(), AtomicU64::new(0))),
            started: std::time::Instant::now(),
        }
    }

    fn slot(&self, ep: Endpoint) -> &(Histogram, AtomicU64) {
        let idx = Endpoint::ALL.iter().position(|e| *e == ep).unwrap();
        &self.slots[idx]
    }

    /// Record one served request.
    pub fn record(&self, ep: Endpoint, latency: Duration, ok: bool) {
        let (hist, errors) = self.slot(ep);
        hist.record(latency);
        if !ok {
            errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Histogram for one endpoint.
    pub fn histogram(&self, ep: Endpoint) -> &Histogram {
        &self.slot(ep).0
    }

    /// Errors recorded for one endpoint.
    pub fn errors(&self, ep: Endpoint) -> u64 {
        self.slot(ep).1.load(Ordering::Relaxed)
    }

    /// Total requests across endpoints.
    pub fn total_requests(&self) -> u64 {
        self.slots.iter().map(|(h, _)| h.count()).sum()
    }

    /// Server uptime in milliseconds.
    pub fn uptime_ms(&self) -> f64 {
        self.started.elapsed().as_secs_f64() * 1e3
    }

    /// Full JSON snapshot for `GET /stats`.
    pub fn to_json(&self) -> Json {
        let endpoints = Endpoint::ALL
            .iter()
            .filter(|ep| self.histogram(**ep).count() > 0 || self.errors(**ep) > 0)
            .map(|ep| {
                let mut obj = match self.histogram(*ep).to_json() {
                    Json::Obj(pairs) => pairs,
                    _ => unreachable!(),
                };
                obj.push(("errors".to_string(), Json::Num(self.errors(*ep) as f64)));
                (ep.name().to_string(), Json::Obj(obj))
            })
            .collect();
        Json::obj(vec![
            ("uptime_ms", Json::Num(self.uptime_ms())),
            ("requests", Json::Num(self.total_requests() as f64)),
            ("endpoints", Json::Obj(endpoints)),
        ])
    }

    /// Aligned text table (for humans: `GET /stats?format=text`).
    pub fn render_text(&self) -> String {
        let rows: Vec<Vec<String>> = Endpoint::ALL
            .iter()
            .filter(|ep| self.histogram(**ep).count() > 0 || self.errors(**ep) > 0)
            .map(|ep| {
                let h = self.histogram(*ep);
                vec![
                    ep.name().to_string(),
                    h.count().to_string(),
                    human::ms(h.mean_ms()),
                    human::ms(h.quantile_ms(0.50)),
                    human::ms(h.quantile_ms(0.99)),
                    human::ms(h.max_ms()),
                    self.errors(*ep).to_string(),
                ]
            })
            .collect();
        human::table(
            &["endpoint", "count", "mean", "p50", "p99", "max", "errors"],
            &rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record_us(100); // bucket upper bound 128 µs
        }
        h.record_us(100_000); // one slow outlier, upper bound 131072 µs
        assert_eq!(h.count(), 100);
        assert!((h.quantile_ms(0.5) - 0.128).abs() < 1e-9, "{}", h.quantile_ms(0.5));
        assert!(h.quantile_ms(0.99) < 1.0); // 99 of 100 are fast
        assert!(h.quantile_ms(1.0) >= 100.0); // the outlier
        assert!(h.max_ms() >= 100.0);
        assert!(h.mean_ms() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
    }

    #[test]
    fn zero_microsecond_sample_lands_in_first_bucket() {
        let h = Histogram::new();
        h.record_us(0);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_ms(0.5) <= 0.001);
    }

    #[test]
    fn stats_records_and_snapshots() {
        let s = ServerStats::new();
        s.record(Endpoint::Spmv, Duration::from_micros(250), true);
        s.record(Endpoint::Spmv, Duration::from_micros(400), true);
        s.record(Endpoint::Ingest, Duration::from_millis(30), false);
        assert_eq!(s.total_requests(), 3);
        assert_eq!(s.errors(Endpoint::Ingest), 1);
        assert_eq!(s.errors(Endpoint::Spmv), 0);
        let j = s.to_json();
        let eps = j.get("endpoints").unwrap();
        assert!(eps.get("spmv").is_some());
        assert!(eps.get("tc").is_none(), "idle endpoints are omitted");
        assert_eq!(eps.get("spmv").unwrap().get("count").unwrap().as_u64(), Some(2));
        let text = s.render_text();
        assert!(text.contains("spmv"));
        assert!(text.contains("ingest"));
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let s = std::sync::Arc::new(ServerStats::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    s.record(Endpoint::Pagerank, Duration::from_micros(t * 50 + i % 97), true);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.histogram(Endpoint::Pagerank).count(), 4000);
    }
}
