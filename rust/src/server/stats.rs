//! Lock-free per-endpoint latency statistics: power-of-two bucketed
//! histograms over microseconds, recorded by worker threads and read by
//! `GET /stats` and the `/metrics` exposition — the service-side
//! analogue of the offline bench harness's median/MAD summaries.
//!
//! The histogram type itself lives in [`crate::obs::hist`] (the
//! observability subsystem shares it with stage-span tracing); it is
//! re-exported here so existing `server::stats::Histogram` paths keep
//! working.

use crate::util::human;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::json::Json;

pub use crate::obs::hist::Histogram;

/// The service's request endpoints (stats slots).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// `POST /graphs` — ingest + prepare.
    Ingest,
    /// `GET /graphs`.
    List,
    /// `POST /graphs/{id}/spmv`.
    Spmv,
    /// `POST /graphs/{id}/pagerank`.
    Pagerank,
    /// `POST /graphs/{id}/sssp`.
    Sssp,
    /// `POST /graphs/{id}/tc`.
    Tc,
    /// `POST /query/batch` — heterogeneous query arrays.
    Batch,
    /// `POST /graphs/{id}/mutate` (plus the manual `/compact` and
    /// `/digest` mutation-surface endpoints, which share the slot).
    Mutate,
    /// `GET /healthz` — pure liveness.
    Healthz,
    /// `GET /readyz` — readiness (503 while preparing or shedding).
    Readyz,
    /// `GET /stats`.
    Stats,
    /// `GET /metrics` — Prometheus exposition.
    Metrics,
    /// `GET /debug/traces`.
    Traces,
}

impl Endpoint {
    /// All endpoints, display order.
    pub const ALL: [Endpoint; 13] = [
        Endpoint::Ingest,
        Endpoint::List,
        Endpoint::Spmv,
        Endpoint::Pagerank,
        Endpoint::Sssp,
        Endpoint::Tc,
        Endpoint::Batch,
        Endpoint::Mutate,
        Endpoint::Healthz,
        Endpoint::Readyz,
        Endpoint::Stats,
        Endpoint::Metrics,
        Endpoint::Traces,
    ];

    /// Stable name used in /stats keys and /metrics labels.
    pub fn name(self) -> &'static str {
        match self {
            Endpoint::Ingest => "ingest",
            Endpoint::List => "list",
            Endpoint::Spmv => "spmv",
            Endpoint::Pagerank => "pagerank",
            Endpoint::Sssp => "sssp",
            Endpoint::Tc => "tc",
            Endpoint::Batch => "batch",
            Endpoint::Mutate => "mutate",
            Endpoint::Healthz => "healthz",
            Endpoint::Readyz => "readyz",
            Endpoint::Stats => "stats",
            Endpoint::Metrics => "metrics",
            Endpoint::Traces => "traces",
        }
    }

    /// Query endpoint from its URL segment.
    pub fn query_from(seg: &str) -> Option<Endpoint> {
        match seg {
            "spmv" => Some(Endpoint::Spmv),
            "pagerank" | "pr" => Some(Endpoint::Pagerank),
            "sssp" => Some(Endpoint::Sssp),
            "tc" => Some(Endpoint::Tc),
            _ => None,
        }
    }
}

/// Aggregated per-endpoint stats for one server instance.
#[derive(Debug)]
pub struct ServerStats {
    slots: [(Histogram, AtomicU64); 13], // (latencies, error count)
    started: std::time::Instant,
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerStats {
    /// Fresh stats (uptime starts now).
    pub fn new() -> ServerStats {
        ServerStats {
            slots: std::array::from_fn(|_| (Histogram::new(), AtomicU64::new(0))),
            started: std::time::Instant::now(),
        }
    }

    fn slot(&self, ep: Endpoint) -> &(Histogram, AtomicU64) {
        let idx = Endpoint::ALL.iter().position(|e| *e == ep).unwrap();
        &self.slots[idx]
    }

    /// Record one served request.
    pub fn record(&self, ep: Endpoint, latency: Duration, ok: bool) {
        let (hist, errors) = self.slot(ep);
        hist.record(latency);
        if !ok {
            errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Histogram for one endpoint.
    pub fn histogram(&self, ep: Endpoint) -> &Histogram {
        &self.slot(ep).0
    }

    /// Errors recorded for one endpoint.
    pub fn errors(&self, ep: Endpoint) -> u64 {
        self.slot(ep).1.load(Ordering::Relaxed)
    }

    /// Total requests across endpoints.
    pub fn total_requests(&self) -> u64 {
        self.slots.iter().map(|(h, _)| h.count()).sum()
    }

    /// Server uptime in milliseconds.
    pub fn uptime_ms(&self) -> f64 {
        self.started.elapsed().as_secs_f64() * 1e3
    }

    /// Full JSON snapshot for `GET /stats`.
    pub fn to_json(&self) -> Json {
        let endpoints = Endpoint::ALL
            .iter()
            .filter(|ep| self.histogram(**ep).count() > 0 || self.errors(**ep) > 0)
            .map(|ep| {
                let mut obj = match self.histogram(*ep).to_json() {
                    Json::Obj(pairs) => pairs,
                    _ => unreachable!(),
                };
                obj.push(("errors".to_string(), Json::Num(self.errors(*ep) as f64)));
                (ep.name().to_string(), Json::Obj(obj))
            })
            .collect();
        Json::obj(vec![
            ("uptime_ms", Json::Num(self.uptime_ms())),
            ("requests", Json::Num(self.total_requests() as f64)),
            ("endpoints", Json::Obj(endpoints)),
        ])
    }

    /// Aligned text table (for humans: `GET /stats?format=text`) — the
    /// full percentile ladder, p50 through p999.
    pub fn render_text(&self) -> String {
        let rows: Vec<Vec<String>> = Endpoint::ALL
            .iter()
            .filter(|ep| self.histogram(**ep).count() > 0 || self.errors(**ep) > 0)
            .map(|ep| {
                let h = self.histogram(*ep);
                vec![
                    ep.name().to_string(),
                    h.count().to_string(),
                    human::ms(h.mean_ms()),
                    human::ms(h.quantile_ms(0.50)),
                    human::ms(h.quantile_ms(0.95)),
                    human::ms(h.quantile_ms(0.99)),
                    human::ms(h.quantile_ms(0.999)),
                    human::ms(h.max_ms()),
                    self.errors(*ep).to_string(),
                ]
            })
            .collect();
        human::table(
            &["endpoint", "count", "mean", "p50", "p95", "p99", "p999", "max", "errors"],
            &rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record_us(100); // bucket upper bound 128 µs
        }
        h.record_us(100_000); // one slow outlier, upper bound 131072 µs
        assert_eq!(h.count(), 100);
        assert!((h.quantile_ms(0.5) - 0.128).abs() < 1e-9, "{}", h.quantile_ms(0.5));
        assert!(h.quantile_ms(0.99) < 1.0); // 99 of 100 are fast
        assert!(h.quantile_ms(1.0) >= 100.0); // the outlier
        assert!(h.max_ms() >= 100.0);
        assert!(h.mean_ms() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
    }

    #[test]
    fn zero_microsecond_sample_lands_in_first_bucket() {
        let h = Histogram::new();
        h.record_us(0);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_ms(0.5) <= 0.001);
    }

    #[test]
    fn stats_records_and_snapshots() {
        let s = ServerStats::new();
        s.record(Endpoint::Spmv, Duration::from_micros(250), true);
        s.record(Endpoint::Spmv, Duration::from_micros(400), true);
        s.record(Endpoint::Ingest, Duration::from_millis(30), false);
        assert_eq!(s.total_requests(), 3);
        assert_eq!(s.errors(Endpoint::Ingest), 1);
        assert_eq!(s.errors(Endpoint::Spmv), 0);
        let j = s.to_json();
        let eps = j.get("endpoints").unwrap();
        assert!(eps.get("spmv").is_some());
        assert!(eps.get("tc").is_none(), "idle endpoints are omitted");
        assert_eq!(eps.get("spmv").unwrap().get("count").unwrap().as_u64(), Some(2));
        let spmv = eps.get("spmv").unwrap();
        assert!(spmv.get("p95_ms").is_some() && spmv.get("p999_ms").is_some());
        let text = s.render_text();
        assert!(text.contains("spmv"));
        assert!(text.contains("ingest"));
        assert!(text.contains("p95") && text.contains("p999"));
    }

    #[test]
    fn metrics_and_traces_have_stats_slots() {
        let s = ServerStats::new();
        s.record(Endpoint::Metrics, Duration::from_micros(90), true);
        s.record(Endpoint::Traces, Duration::from_micros(120), true);
        assert_eq!(s.histogram(Endpoint::Metrics).count(), 1);
        assert_eq!(s.histogram(Endpoint::Traces).count(), 1);
        assert_eq!(Endpoint::ALL.len(), 13);
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let s = std::sync::Arc::new(ServerStats::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    s.record(Endpoint::Pagerank, Duration::from_micros(t * 50 + i % 97), true);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.histogram(Endpoint::Pagerank).count(), 4000);
    }
}
