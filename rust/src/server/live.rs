//! Live mutable graphs: the WAL-backed delta overlay, crash recovery,
//! and the BOBA-driven background compactor.
//!
//! A [`LiveGraph`] pairs a registry artifact (the frozen base CSR,
//! possibly relabeled by BOBA) with a [`DeltaOverlay`] of post-prepare
//! mutations and the [`Wal`](super::wal::Wal) that makes them durable.
//! The data flow for one `POST /graphs/{id}/mutate`:
//!
//! ```text
//! validate (orig ids < n) → WAL append (group-commit fsync) → ACK
//!        → map orig→artifact via the base perm → delta.apply (COW)
//! ```
//!
//! Queries read an atomic `(base, delta, epoch)` snapshot and run the
//! merged kernels in [`crate::graph::delta`]; a query admitted on epoch
//! `e` finishes on epoch `e` even if the compactor swaps mid-flight
//! (its snapshot holds `Arc`s).
//!
//! ## Epoch-swap protocol (compaction)
//!
//! When the overlay crosses `--compact-threshold` the compactor:
//!
//! 1. under the writer lock: snapshots `(base, delta, |pending|)` and
//!    **rotates** the WAL so every snapshotted record lives in a
//!    retired-eligible segment;
//! 2. materializes base ⊕ delta and relabels it back to the original
//!    label space (the artifact space dies with the old perm);
//! 3. writes the checkpoint `.ckpt.bcoo` via tmp+rename — after this
//!    rename, recovery prefers the checkpoint over re-ingesting;
//! 4. **re-runs the full reorder pipeline (BOBA + convert + transpose
//!    + format)** on the merged COO — the paper's "reordering is cheap
//!    enough to re-run inside the pipeline" claim, live;
//! 5. under the writer lock: swaps `base` to the new epoch and rebases
//!    the post-rotation pending tail onto the new perm;
//! 6. retires the rotated WAL prefix (only now — the checkpoint covers
//!    it) and republishes the artifact in the registry.
//!
//! A crash at any point leaves a recoverable disk state: before the
//! rename, recovery replays the old checkpoint/source + the full WAL;
//! after it, the new checkpoint + the unretired segments — replay is
//! idempotent (upsert/delete are absolute, last-write-wins per pair),
//! so the checkpoint/WAL overlap in the post-rename window is harmless.
//!
//! ## Digests
//!
//! Crash-equivalence is asserted on [`digest`]: a commutative FNV-64
//! multiset hash over **original-label** edges. Restart re-runs the
//! racy Algorithm-3 reorder and generally lands on a different
//! permutation, so an artifact-space hash would never compare equal;
//! the original-space multiset hash is invariant under relabeling and
//! under merge order, which makes it exact across crashes, restarts,
//! and compactions.

use crate::graph::delta::{merged_coo, DeltaOp, DeltaOverlay};
use crate::graph::io::bcoo::{self, fnv64};
use crate::graph::Coo;
use crate::obs::chaos;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::registry::{GraphRegistry, PreparedGraph};
use super::wal::{self, ScanReport, Wal, WalOp, OP_DELETE, OP_UPSERT};

/// Acknowledgement for one durable mutation batch.
#[derive(Debug, Clone, Copy)]
pub struct MutateAck {
    /// WAL sequence number of the batch record.
    pub seq: u64,
    /// Epoch the batch was applied on.
    pub epoch: u64,
    /// Overlay size after applying (upserts + tombstones).
    pub delta_entries: usize,
    /// Ops in the batch.
    pub ops: usize,
}

/// The mutable state behind one live graph, swapped atomically at
/// compaction.
struct LiveInner {
    base: Arc<PreparedGraph>,
    delta: Arc<DeltaOverlay>,
    /// Original-space ops acked since the last compaction snapshot —
    /// the in-memory twin of the live WAL suffix.
    pending: Vec<WalOp>,
    epoch: u64,
}

/// A registry artifact opened for mutation: base + overlay + WAL.
pub struct LiveGraph {
    /// Registry id (`dataset@scheme`).
    pub id: String,
    dataset: String,
    scheme: String,
    wal: Wal,
    /// Serializes mutators (and the compactor's snapshot/swap windows)
    /// without blocking readers, who only take `inner` briefly.
    write: Mutex<()>,
    inner: Mutex<LiveInner>,
    compacting: AtomicBool,
    /// Acked mutation batches.
    batches: AtomicU64,
    /// Acked individual ops.
    ops: AtomicU64,
}

impl LiveGraph {
    /// Open the live state for `base`, replaying `scan` (the WAL replay
    /// report — empty for a brand-new live graph). Ops that no longer
    /// fit the vertex space are dropped with a warning instead of
    /// poisoning recovery.
    pub fn open(
        dir: &Path,
        base: Arc<PreparedGraph>,
        epoch: u64,
        scan: ScanReport,
    ) -> Result<Arc<LiveGraph>> {
        let key = wal::key_for(&base.id);
        let wal = Wal::open(dir, &key, scan.last_seg, scan.next_seq)?;
        let mapped = to_artifact_ops(&scan.ops, &base);
        let delta = DeltaOverlay::from_ops(base.n(), &mapped);
        Ok(Arc::new(LiveGraph {
            id: base.id.clone(),
            dataset: base.dataset.clone(),
            scheme: base.scheme.clone(),
            wal,
            write: Mutex::new(()),
            inner: Mutex::new(LiveInner {
                base,
                delta: Arc::new(delta),
                pending: scan.ops,
                epoch,
            }),
            compacting: AtomicBool::new(false),
            batches: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        }))
    }

    /// Atomic query snapshot: `(base, delta, epoch)`. Queries holding
    /// the returned `Arc`s finish on this epoch regardless of
    /// concurrent compaction.
    pub fn view(&self) -> (Arc<PreparedGraph>, Arc<DeltaOverlay>, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.base.clone(), inner.delta.clone(), inner.epoch)
    }

    /// Overlay entries right now (the compaction-threshold signal).
    pub fn delta_entries(&self) -> usize {
        self.inner.lock().unwrap().delta.len()
    }

    /// Acked batch count.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Acked op count.
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    /// True while a compaction is running.
    pub fn compacting(&self) -> bool {
        self.compacting.load(Ordering::Relaxed)
    }

    /// Apply one mutation batch: validate, append to the WAL (the ack
    /// is durable before this returns), then fold into the overlay.
    /// Vertex ids are **original labels**; a batch naming a vertex
    /// `>= n` is rejected before any byte is written.
    pub fn mutate(&self, ops: &[WalOp]) -> Result<MutateAck> {
        let _w = self.write.lock().unwrap();
        let n = {
            let inner = self.inner.lock().unwrap();
            inner.base.n()
        };
        for op in ops {
            if op.u as usize >= n || op.v as usize >= n {
                bail!("vertex id out of range: ({}, {}) on a graph of n={n}", op.u, op.v);
            }
            if op.kind != OP_UPSERT && op.kind != OP_DELETE {
                bail!("unknown op kind {}", op.kind);
            }
        }
        let seq = self.wal.append(ops)?;
        let mut inner = self.inner.lock().unwrap();
        let mapped = to_artifact_ops(ops, &inner.base);
        inner.delta = Arc::new(inner.delta.apply(&mapped));
        inner.pending.extend_from_slice(ops);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.ops.fetch_add(ops.len() as u64, Ordering::Relaxed);
        Ok(MutateAck {
            seq,
            epoch: inner.epoch,
            delta_entries: inner.delta.len(),
            ops: ops.len(),
        })
    }

    /// Current epoch.
    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    /// Original-space multiset digest of the live graph (see module
    /// docs) — the crash-equivalence observable behind
    /// `GET /graphs/{id}/digest`.
    pub fn digest(&self) -> u64 {
        let (base, delta, _) = self.view();
        digest(&base, &delta)
    }

    /// JSON row appended to the artifact's `GET /graphs` entry.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let (base, delta, epoch) = self.view();
        Json::obj(vec![
            ("epoch", Json::Num(epoch as f64)),
            ("delta_entries", Json::Num(delta.len() as f64)),
            ("merged_m", Json::Num(delta.merged_m(&base.csr) as f64)),
            ("batches", Json::Num(self.batches() as f64)),
            ("ops", Json::Num(self.ops() as f64)),
            ("wal_bytes", Json::Num(self.wal.appended_bytes() as f64)),
            ("compacting", Json::Bool(self.compacting())),
        ])
    }
}

/// Map original-space WAL ops onto a specific artifact: relabel through
/// the artifact's perm (identity for `none`), normalize weights to 1.0
/// on unweighted bases, and drop (with a warning) ops that no longer
/// fit the vertex space — recovery must not die on a stale log.
fn to_artifact_ops(ops: &[WalOp], base: &PreparedGraph) -> Vec<DeltaOp> {
    let n = base.n();
    let weighted = base.csr.vals.is_some();
    let map = |x: u32| -> u32 {
        match &base.perm {
            Some(p) => p.new_of_old()[x as usize],
            None => x,
        }
    };
    let mut out = Vec::with_capacity(ops.len());
    for op in ops {
        if op.u as usize >= n || op.v as usize >= n {
            eprintln!(
                "[boba] dropping wal op ({}, {}) outside n={n} of {}",
                op.u, op.v, base.id
            );
            continue;
        }
        out.push(match op.kind {
            OP_UPSERT => DeltaOp::Upsert {
                src: map(op.u),
                dst: map(op.v),
                w: if weighted { op.w } else { 1.0 },
            },
            _ => DeltaOp::Delete { src: map(op.u), dst: map(op.v) },
        });
    }
    out
}

/// Label-invariant, order-invariant digest of base ⊕ delta: a wrapping
/// sum of per-edge FNV-64 hashes over original-label edges, folded with
/// the vertex count. Exact (integer) — equal iff the original-space
/// edge multisets (and weights, when present) are equal.
pub fn digest(base: &PreparedGraph, delta: &DeltaOverlay) -> u64 {
    let coo = merged_coo(&base.csr, delta);
    let old_of_new: Option<Vec<u32>> = base.perm.as_ref().map(|p| p.order());
    let back = |x: u32| -> u32 {
        match &old_of_new {
            Some(m) => m[x as usize],
            None => x,
        }
    };
    let mut sum: u64 = 0;
    let mut buf = [0u8; 12];
    for i in 0..coo.m() {
        buf[0..4].copy_from_slice(&back(coo.src[i]).to_le_bytes());
        buf[4..8].copy_from_slice(&back(coo.dst[i]).to_le_bytes());
        let wbits = coo.vals.as_ref().map_or(0u32, |v| v[i].to_bits());
        buf[8..12].copy_from_slice(&wbits.to_le_bytes());
        sum = sum.wrapping_add(fnv64(&buf));
    }
    sum ^ fnv64(&(coo.n() as u64).to_le_bytes())
}

/// Synchronous compaction (the `POST /graphs/{id}/compact` path and the
/// body of the background compactor). Returns `Ok(false)` when another
/// compaction holds the slot or the overlay is empty. See the module
/// docs for the staged protocol and its crash windows.
pub fn compact(registry: &GraphRegistry, live: &Arc<LiveGraph>) -> Result<bool> {
    // ordering: SeqCst — the compaction slot latch; pairs with the
    // release store below and with `/readyz`'s load so at most one
    // compactor runs and its staged effects are totally ordered.
    if live.compacting.swap(true, Ordering::SeqCst) {
        return Ok(false);
    }
    let out = compact_inner(registry, live);
    // ordering: SeqCst — releases the slot; pairs with the swap above.
    live.compacting.store(false, Ordering::SeqCst);
    out
}

fn compact_inner(registry: &GraphRegistry, live: &Arc<LiveGraph>) -> Result<bool> {
    let dir = registry
        .wal_dir()
        .context("compaction requires a wal dir")?
        .to_path_buf();
    let key = wal::key_for(&live.id);
    // `compact-fail:STAGE` injects an abort at one staged crash window:
    // 0 = pre-checkpoint, 1 = post-checkpoint (before the swap). The
    // budget is consumed here, once per compaction attempt.
    let fail_stage = chaos::fire("compact-fail");

    // Stage 1 — snapshot + rotate, writers briefly excluded so the
    // rotated prefix holds exactly the snapshotted records.
    let (base, delta, pending_len, epoch, old_seg) = {
        let _w = live.write.lock().unwrap();
        let (base, delta, pending_len, epoch) = {
            let inner = live.inner.lock().unwrap();
            (inner.base.clone(), inner.delta.clone(), inner.pending.len(), inner.epoch)
        };
        let old_seg = live.wal.rotate()?;
        (base, delta, pending_len, epoch, old_seg)
    };
    if delta.is_empty() {
        return Ok(false);
    }

    // Stage 2 — materialize base ⊕ delta back in the original label
    // space (the only space that survives the re-reorder).
    let merged = merged_coo(&base.csr, &delta);
    let orig = match &base.perm {
        Some(p) => merged.relabeled(&p.order()),
        None => merged,
    };
    if fail_stage == Some(0) {
        bail!("injected compact-fail pre-checkpoint");
    }

    // Stage 3 — checkpoint via tmp+rename. After the rename, recovery
    // prefers this file over re-ingesting the dataset spec.
    let ckpt = wal::ckpt_path(&dir, &key);
    let tmp = dir.join(format!("{key}.ckpt.tmp.{}", std::process::id()));
    bcoo::write_bcoo(&orig, &tmp).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &ckpt)
        .with_context(|| format!("renaming checkpoint to {}", ckpt.display()))?;
    if fail_stage == Some(1) {
        bail!("injected compact-fail post-checkpoint");
    }

    // Stage 4 — re-run the reorder pipeline online: BOBA + convert +
    // transpose (+ format) on the merged graph. This is the paper's
    // amortization claim exercised live.
    let next_epoch = epoch + 1;
    let g = Arc::new(registry.rebuild_from_coo(&live.dataset, &live.scheme, orig, next_epoch)?);

    // Stage 5 — swap: rebase the post-rotation pending tail onto the
    // new perm and publish the new epoch. Queries admitted before this
    // block finish on their old (base, delta) snapshot.
    {
        let _w = live.write.lock().unwrap();
        let mut inner = live.inner.lock().unwrap();
        let tail = inner.pending.split_off(pending_len);
        inner.pending = tail;
        let mapped = to_artifact_ops(&inner.pending, &g);
        inner.delta = Arc::new(DeltaOverlay::from_ops(g.n(), &mapped));
        inner.base = g.clone();
        inner.epoch = next_epoch;
    }
    registry.publish(&live.id, g);
    wal::write_meta(&dir, &key, &live.id, &live.dataset, &live.scheme, next_epoch)?;

    // Stage 6 — only now is the rotated prefix redundant.
    live.wal.retire_through(old_seg)?;
    registry.note_compaction();
    Ok(true)
}

/// Fire-and-forget background compaction when the overlay has crossed
/// the registry's threshold and no compaction is running. The spawned
/// thread is tracked by the registry's active-compaction gauge.
pub fn maybe_compact_bg(registry: &Arc<GraphRegistry>, live: &Arc<LiveGraph>) {
    let threshold = registry.compact_threshold();
    if threshold == 0 || live.delta_entries() < threshold || live.compacting() {
        return;
    }
    let registry = registry.clone();
    let live = live.clone();
    registry.clone().compaction_started();
    // lint: allow(raw-spawn): background compaction is a long-running,
    // fire-and-forget job; parking it on the compute pool would steal a
    // kernel worker for the entire BOBA re-run and risk deadlock when
    // compaction itself dispatches pool work.
    let spawned = std::thread::Builder::new()
        .name("boba-compact".to_string())
        .spawn(move || {
            match compact(&registry, &live) {
                Ok(true) => {}
                Ok(false) => {}
                Err(e) => eprintln!("[boba] compaction of {} failed: {e:#}", live.id),
            }
            registry.compaction_finished();
        });
    if spawned.is_err() {
        // Thread spawn failure: undo the gauge; the next mutate retries.
        eprintln!("[boba] could not spawn compactor thread");
    }
}

/// Recover every graph with WAL state in `dir`, sequentially, replaying
/// each log into a fresh artifact and registering it. `shutdown` is
/// honored between records and between graphs: a set flag aborts
/// immediately **without truncating undamaged segments** (only
/// proven-torn final-segment tails are ever truncated, and only while
/// the flag is clear). The registry's `recovering` gauge must already
/// count the metas (set synchronously at server start so `/readyz`
/// reports `recovering` from the first request).
pub fn recover_all(registry: &Arc<GraphRegistry>, shutdown: &AtomicBool) {
    let Some(dir) = registry.wal_dir().map(Path::to_path_buf) else {
        return;
    };
    let metas = match wal::list_metas(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("[boba] wal recovery: cannot list {}: {e:#}", dir.display());
            registry.set_recovering(0);
            return;
        }
    };
    for meta in metas {
        if shutdown.load(Ordering::Relaxed) {
            registry.set_recovering(0);
            return;
        }
        if let Err(e) = recover_one(registry, &dir, &meta, shutdown) {
            eprintln!("[boba] wal recovery of {} failed: {e:#}", meta.id);
        }
        registry.dec_recovering();
    }
}

fn recover_one(
    registry: &Arc<GraphRegistry>,
    dir: &Path,
    meta: &wal::WalMeta,
    shutdown: &AtomicBool,
) -> Result<()> {
    let report = wal::scan(dir, &meta.key, shutdown, true)?;
    if report.aborted {
        bail!("shutdown during replay (log left untouched)");
    }
    // Base: the checkpoint if one has landed, else the dataset recipe.
    let ckpt = wal::ckpt_path(dir, &meta.key);
    let coo: Coo = if ckpt.exists() {
        bcoo::read_bcoo(&ckpt).with_context(|| format!("reading {}", ckpt.display()))?
    } else {
        registry.load_base_coo(&meta.dataset)?
    };
    if shutdown.load(Ordering::Relaxed) {
        bail!("shutdown during replay (log left untouched)");
    }
    let g = Arc::new(registry.rebuild_from_coo(&meta.dataset, &meta.scheme, coo, meta.epoch)?);
    let live = LiveGraph::open(dir, g.clone(), meta.epoch, report)?;
    registry.publish(&meta.id, g);
    registry.install_live(live);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::registry::RegistryConfig;

    fn wal_registry(tag: &str) -> (Arc<GraphRegistry>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "boba-live-{tag}-{}-{:x}",
            std::process::id(),
            fnv64(tag.as_bytes())
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = GraphRegistry::new(RegistryConfig {
            capacity: 4,
            batch: 500,
            in_flight: 2,
            seed: 7,
            wal_dir: Some(dir.clone()),
            compact_threshold: 0, // manual compaction in tests
            ..RegistryConfig::default()
        });
        (Arc::new(r), dir)
    }

    fn up(u: u32, v: u32) -> WalOp {
        WalOp { kind: OP_UPSERT, u, v, w: 1.0 }
    }

    fn del(u: u32, v: u32) -> WalOp {
        WalOp { kind: OP_DELETE, u, v, w: 0.0 }
    }

    #[test]
    fn mutate_applies_and_digest_tracks_edge_multiset() {
        let (r, dir) = wal_registry("mutate");
        let (g, _) = r.get_or_prepare("pa:1000:4", "boba").unwrap();
        let live = r.live_for(&g).unwrap();
        let d0 = live.digest();
        let ack = live.mutate(&[up(1, 2), del(3, 4)]).unwrap();
        assert_eq!(ack.seq, 0);
        assert_eq!(ack.ops, 2);
        let d1 = live.digest();
        assert_ne!(d0, d1, "mutations must move the digest");
        // Upserting an identical edge again is idempotent.
        live.mutate(&[up(1, 2)]).unwrap();
        assert_eq!(live.digest(), d1);
        // Out-of-range ids are rejected before any WAL write.
        let before = live.batches();
        assert!(live.mutate(&[up(0, 1_000_000)]).is_err());
        assert_eq!(live.batches(), before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn digest_is_label_invariant_across_schemes() {
        // The same dataset under boba and none serves the same original
        // edge multiset, so the live digests agree even though the
        // artifact CSRs are differently labeled.
        let (r, dir) = wal_registry("label-inv");
        let (a, _) = r.get_or_prepare("pa:800:4", "boba").unwrap();
        let (b, _) = r.get_or_prepare("pa:800:4", "none").unwrap();
        let la = r.live_for(&a).unwrap();
        let lb = r.live_for(&b).unwrap();
        assert_eq!(la.digest(), lb.digest());
        la.mutate(&[up(5, 6), del(7, 8)]).unwrap();
        lb.mutate(&[up(5, 6), del(7, 8)]).unwrap();
        assert_eq!(la.digest(), lb.digest(), "same orig-space ops, same digest");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_folds_delta_and_preserves_digest() {
        let (r, dir) = wal_registry("compact");
        let (g, _) = r.get_or_prepare("pa:1200:4", "boba").unwrap();
        let live = r.live_for(&g).unwrap();
        for i in 0..40u32 {
            live.mutate(&[up(i, (i + 13) % 1200), del((i * 3) % 1200, (i * 7) % 1200)])
                .unwrap();
        }
        let before = live.digest();
        let (_, _, epoch0) = live.view();
        assert!(compact(&r, &live).unwrap());
        let (base, delta, epoch1) = live.view();
        assert_eq!(epoch1, epoch0 + 1, "compaction bumps the epoch");
        assert!(delta.is_empty(), "the overlay folds into the new base");
        assert_eq!(live.digest(), before, "digest is invariant under compaction");
        assert_eq!(r.compactions(), 1);
        // The registry now serves the new epoch.
        let served = r.get(&live.id).expect("compacted artifact stays registered");
        assert!(Arc::ptr_eq(&served, &base));
        // Mutations keep working on the new epoch.
        live.mutate(&[up(3, 9)]).unwrap();
        assert_ne!(live.digest(), before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_replays_into_equal_digest() {
        let (r, dir) = wal_registry("recover");
        let (g, _) = r.get_or_prepare("pa:900:4", "boba").unwrap();
        let live = r.live_for(&g).unwrap();
        for i in 0..25u32 {
            live.mutate(&[up(i, (i + 41) % 900)]).unwrap();
        }
        live.mutate(&[del(0, 41)]).unwrap();
        let want = live.digest();

        // A "restarted" registry over the same wal dir (same seed).
        let r2 = Arc::new(GraphRegistry::new(RegistryConfig {
            capacity: 4,
            batch: 500,
            in_flight: 2,
            seed: 7,
            wal_dir: Some(dir.clone()),
            ..RegistryConfig::default()
        }));
        r2.set_recovering(wal::list_metas(&dir).unwrap().len());
        let stop = AtomicBool::new(false);
        recover_all(&r2, &stop);
        assert_eq!(r2.recovering(), 0, "recovery drains the gauge");
        let live2 = r2.live_graph(&g.id).expect("recovered live graph");
        assert_eq!(
            live2.digest(),
            want,
            "restart + replay must reproduce the never-crashed digest"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_after_compaction_uses_the_checkpoint() {
        let (r, dir) = wal_registry("recover-ckpt");
        let (g, _) = r.get_or_prepare("pa:700:4", "boba").unwrap();
        let live = r.live_for(&g).unwrap();
        for i in 0..30u32 {
            live.mutate(&[up((i * 5) % 700, (i * 11) % 700)]).unwrap();
        }
        assert!(compact(&r, &live).unwrap());
        live.mutate(&[up(1, 2), del(3, 4)]).unwrap(); // post-compaction tail
        let want = live.digest();
        assert!(wal::ckpt_path(&dir, &wal::key_for(&g.id)).exists());

        let r2 = Arc::new(GraphRegistry::new(RegistryConfig {
            capacity: 4,
            batch: 500,
            in_flight: 2,
            seed: 7,
            wal_dir: Some(dir.clone()),
            ..RegistryConfig::default()
        }));
        let stop = AtomicBool::new(false);
        r2.set_recovering(1);
        recover_all(&r2, &stop);
        let live2 = r2.live_graph(&g.id).expect("recovered live graph");
        assert_eq!(live2.digest(), want);
        assert!(live2.epoch() >= 1, "epoch persisted through the meta");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_compaction_leaves_a_recoverable_equal_twin() {
        for stage in [0u64, 1] {
            let _l = chaos::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
            let tag = format!("compact-fail-{stage}");
            let (r, dir) = wal_registry(&tag);
            let (g, _) = r.get_or_prepare("pa:600:4", "boba").unwrap();
            let live = r.live_for(&g).unwrap();
            for i in 0..20u32 {
                live.mutate(&[up((i * 7) % 600, (i * 13) % 600)]).unwrap();
            }
            let want = live.digest();
            chaos::set_spec(&format!("compact-fail:{stage}:1")).unwrap();
            let err = compact(&r, &live).unwrap_err().to_string();
            chaos::clear();
            assert!(err.contains("compact-fail"), "stage {stage}: {err}");
            // In-process state is untouched (the swap never ran)…
            assert_eq!(live.digest(), want, "stage {stage}");
            // …and a cold restart over the crash-state disk agrees too.
            let r2 = Arc::new(GraphRegistry::new(RegistryConfig {
                capacity: 4,
                batch: 500,
                in_flight: 2,
                seed: 7,
                wal_dir: Some(dir.clone()),
                ..RegistryConfig::default()
            }));
            let stop = AtomicBool::new(false);
            r2.set_recovering(1);
            recover_all(&r2, &stop);
            let live2 = r2.live_graph(&g.id).expect("recovered live graph");
            assert_eq!(
                live2.digest(),
                want,
                "mid-compaction crash at stage {stage} must recover digest-equal"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn merged_queries_match_materialized_base() {
        use crate::algos::spmv;
        use crate::convert;
        use crate::graph::delta;
        let (r, dir) = wal_registry("merged-query");
        let (g, _) = r.get_or_prepare("pa:500:4", "boba").unwrap();
        let live = r.live_for(&g).unwrap();
        live.mutate(&[up(0, 7), up(3, 4), del(1, 0)]).unwrap();
        let (base, d, _) = live.view();
        let x: Vec<f32> = (0..base.n()).map(|i| (i % 13) as f32).collect();
        let merged = delta::spmv_merged(&base.csr, &d, &x);
        let mat = convert::coo_to_csr(&delta::merged_coo(&base.csr, &d));
        let want = spmv::spmv_pull(&mat, &x);
        for v in 0..base.n() {
            assert_eq!(merged[v].to_bits(), want[v].to_bits(), "row {v}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
