//! Admission control: per-tenant token buckets, a global in-flight
//! gate with bounded parking, and the load-shedding ladder.
//!
//! Every query request passes three checks, cheapest first:
//!
//! 1. **Rate** — a token bucket per tenant (`x-tenant` header,
//!    `"default"` otherwise) refilled at `--rate` tokens/sec up to
//!    `--burst`. An empty bucket is a `429 Too Many Requests` with a
//!    `Retry-After` priced from the refill rate.
//! 2. **Shed** — when the in-flight gate is saturated, *expensive*
//!    query kinds (triangle counting, PageRank) are refused immediately
//!    with `503` instead of queueing: a cheap SpMV behind a parked TC
//!    would otherwise inherit its whole queue delay, and the expensive
//!    kinds are exactly the ones a loaded server cannot afford to
//!    start. `/readyz` reports `degraded` while this ladder is active.
//! 3. **Queue** — up to `--max-inflight` requests execute; up to the
//!    same number again park on a condvar (FIFO by wakeup) waiting for
//!    a slot. The parking is deadline-aware — a waiter whose
//!    `x-deadline-ms` budget runs out detaches with `504` instead of
//!    executing work nobody is waiting for — and `Server::shutdown`
//!    releases every parked waiter with `503`. Beyond the parking cap
//!    the request is refused with `503 queue full`.
//!
//! With both knobs at their defaults (`--rate 0 --max-inflight 0`) the
//! whole module is two integer compares per request — the admission
//! path adds nothing to an unconfigured server.
//!
//! Rejections are counted per `(tenant, reason)` and surfaced in
//! `/stats` and the `boba_admission_rejected_total{tenant,reason}` and
//! `boba_inflight` metric families.

use crate::util::{deadline, Json};
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Tenant label used when the request carries no `x-tenant` header.
pub const DEFAULT_TENANT: &str = "default";
/// Distinct-tenant cap for the bucket and counter maps: tenants beyond
/// it share one `"other"` bucket so a label-spraying client cannot
/// balloon server memory or metric cardinality.
pub const MAX_TENANTS: usize = 256;

/// Admission knobs (all off by default — see the module docs).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Token-bucket refill, tokens/sec per tenant; `0.0` disables rate
    /// limiting.
    pub rate: f64,
    /// Token-bucket capacity; `0.0` defaults to `max(rate, 1)`.
    pub burst: f64,
    /// Concurrent-execution cap (an equal number may park behind it);
    /// `0` disables the gate and the shed ladder.
    pub max_inflight: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { rate: 0.0, burst: 0.0, max_inflight: 0 }
    }
}

/// Why a request was refused admission. Maps to the HTTP reply in
/// `Router::handle`: 429 for rate, 503 for shed/queue/shutdown, 504
/// for deadline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Reject {
    /// Tenant bucket empty — retry after the bucket refills.
    RateLimited {
        /// Seconds until one token is available again.
        retry_after_s: f64,
    },
    /// Expensive kind refused while the gate is saturated.
    Shed,
    /// Parking queue is full.
    QueueFull,
    /// Deadline expired while parked for a slot.
    DeadlineExceeded,
    /// Server is shutting down.
    ShuttingDown,
}

impl Reject {
    /// Stable reason label for counters and metrics.
    pub fn reason(&self) -> &'static str {
        match self {
            Reject::RateLimited { .. } => "rate",
            Reject::Shed => "shed",
            Reject::QueueFull => "queue-full",
            Reject::DeadlineExceeded => "deadline",
            Reject::ShuttingDown => "shutdown",
        }
    }

    /// Suggested `Retry-After` in integer seconds (HTTP wants whole
    /// seconds; always at least 1 so clients actually back off).
    pub fn retry_after(&self) -> u64 {
        match self {
            Reject::RateLimited { retry_after_s } => (retry_after_s.ceil() as u64).max(1),
            Reject::Shed | Reject::QueueFull => 1,
            Reject::DeadlineExceeded | Reject::ShuttingDown => 1,
        }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

#[derive(Default)]
struct Gate {
    inflight: usize,
    queued: usize,
    down: bool,
}

/// The shared admission state: one per server, threaded through the
/// router alongside the registry.
pub struct Admission {
    cfg: AdmissionConfig,
    buckets: Mutex<HashMap<String, Bucket>>,
    gate: Mutex<Gate>,
    cv: Condvar,
    rejected: Mutex<BTreeMap<(String, &'static str), u64>>,
    deadline_hits: AtomicU64,
}

/// RAII in-flight slot: dropping it releases the slot and wakes one
/// parked waiter. Inactive when the gate is unconfigured.
pub struct Permit<'a> {
    adm: &'a Admission,
    counted: bool,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        if self.counted {
            let mut g = self.adm.gate.lock().unwrap();
            g.inflight = g.inflight.saturating_sub(1);
            drop(g);
            self.adm.cv.notify_one();
        }
    }
}

impl Admission {
    /// Build from config.
    pub fn new(cfg: AdmissionConfig) -> Self {
        Self {
            cfg,
            buckets: Mutex::new(HashMap::new()),
            gate: Mutex::new(Gate::default()),
            cv: Condvar::new(),
            rejected: Mutex::new(BTreeMap::new()),
            deadline_hits: AtomicU64::new(0),
        }
    }

    /// Effective burst capacity (see [`AdmissionConfig::burst`]).
    fn burst(&self) -> f64 {
        if self.cfg.burst > 0.0 {
            self.cfg.burst
        } else {
            self.cfg.rate.max(1.0)
        }
    }

    /// Run the admission ladder for one query request. `expensive`
    /// marks shed-first kinds (tc, pagerank). Uses the thread-local
    /// [`deadline`] while parked. On `Err` the rejection has already
    /// been counted against `tenant`.
    pub fn admit(&self, tenant: &str, expensive: bool) -> Result<Permit<'_>, Reject> {
        if let Err(r) = self.take_token(tenant) {
            return Err(self.reject(tenant, r));
        }
        if self.cfg.max_inflight == 0 {
            return Ok(Permit { adm: self, counted: false });
        }
        let cap = self.cfg.max_inflight;
        let mut g = self.gate.lock().unwrap();
        if g.down {
            return Err(self.reject(tenant, Reject::ShuttingDown));
        }
        if g.inflight < cap {
            g.inflight += 1;
            return Ok(Permit { adm: self, counted: true });
        }
        // Saturated: shed expensive kinds instead of parking them.
        if expensive {
            return Err(self.reject(tenant, Reject::Shed));
        }
        if g.queued >= cap {
            return Err(self.reject(tenant, Reject::QueueFull));
        }
        g.queued += 1;
        loop {
            // Deadline-aware park: wake on a freed slot, shutdown, or
            // the request deadline running out (250 ms poll bounds the
            // no-deadline shutdown race without busy-waiting).
            let budget = deadline::remaining().unwrap_or(Duration::from_millis(250));
            if budget.is_zero() {
                g.queued -= 1;
                return Err(self.reject(tenant, Reject::DeadlineExceeded));
            }
            let (gg, _timeout) =
                self.cv.wait_timeout(g, budget.min(Duration::from_millis(250))).unwrap();
            g = gg;
            if g.down {
                g.queued -= 1;
                return Err(self.reject(tenant, Reject::ShuttingDown));
            }
            if g.inflight < cap {
                g.queued -= 1;
                g.inflight += 1;
                return Ok(Permit { adm: self, counted: true });
            }
            if deadline::expired() {
                g.queued -= 1;
                return Err(self.reject(tenant, Reject::DeadlineExceeded));
            }
        }
    }

    fn take_token(&self, tenant: &str) -> Result<(), Reject> {
        if self.cfg.rate <= 0.0 {
            return Ok(());
        }
        let burst = self.burst();
        let mut buckets = self.buckets.lock().unwrap();
        let key = if buckets.len() >= MAX_TENANTS && !buckets.contains_key(tenant) {
            "other"
        } else {
            tenant
        };
        let now = Instant::now();
        let b = buckets
            .entry(key.to_string())
            .or_insert_with(|| Bucket { tokens: burst, last: now });
        b.tokens = (b.tokens + now.duration_since(b.last).as_secs_f64() * self.cfg.rate)
            .min(burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err(Reject::RateLimited { retry_after_s: (1.0 - b.tokens) / self.cfg.rate })
        }
    }

    fn reject(&self, tenant: &str, r: Reject) -> Reject {
        let mut m = self.rejected.lock().unwrap();
        let key = if m.len() >= MAX_TENANTS && !m.keys().any(|(t, _)| t == tenant) {
            "other"
        } else {
            tenant
        };
        *m.entry((key.to_string(), r.reason())).or_insert(0) += 1;
        r
    }

    /// Count a deadline expiry observed *after* admission (at dequeue,
    /// pre-dispatch, or mid-kernel) — feeds
    /// `boba_deadline_exceeded_total`.
    pub fn note_deadline_hit(&self) {
        self.deadline_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Total post-admission deadline expiries.
    pub fn deadline_hits(&self) -> u64 {
        self.deadline_hits.load(Ordering::Relaxed)
    }

    /// Currently executing requests (the `boba_inflight` gauge; 0 when
    /// the gate is unconfigured).
    pub fn inflight(&self) -> usize {
        self.gate.lock().unwrap().inflight
    }

    /// True while the gate is saturated (executing at cap or waiters
    /// parked) — the shed ladder is active and `/readyz` degrades.
    pub fn pressured(&self) -> bool {
        if self.cfg.max_inflight == 0 {
            return false;
        }
        let g = self.gate.lock().unwrap();
        g.inflight >= self.cfg.max_inflight || g.queued > 0
    }

    /// Release every parked waiter with [`Reject::ShuttingDown`]; new
    /// admissions are refused from now on.
    pub fn shutdown(&self) {
        self.gate.lock().unwrap().down = true;
        self.cv.notify_all();
    }

    /// Snapshot of the per-`(tenant, reason)` rejection counters.
    pub fn rejected_snapshot(&self) -> Vec<(String, &'static str, u64)> {
        self.rejected
            .lock()
            .unwrap()
            .iter()
            .map(|((t, r), n)| (t.clone(), *r, *n))
            .collect()
    }

    /// Admission state for `/stats`:
    /// `{"inflight":..,"pressured":..,"deadline_exceeded":..,"rejected":{"tenant:reason":n}}`.
    pub fn to_json(&self) -> Json {
        let rejected = Json::Obj(
            self.rejected_snapshot()
                .into_iter()
                .map(|(t, r, n)| (format!("{t}:{r}"), Json::Num(n as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("inflight", Json::Num(self.inflight() as f64)),
            ("pressured", Json::Bool(self.pressured())),
            ("deadline_exceeded", Json::Num(self.deadline_hits() as f64)),
            ("rejected", rejected),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn adm(rate: f64, burst: f64, max_inflight: usize) -> Admission {
        Admission::new(AdmissionConfig { rate, burst, max_inflight })
    }

    #[test]
    fn unconfigured_admits_everything() {
        let a = adm(0.0, 0.0, 0);
        for _ in 0..1000 {
            assert!(a.admit("t", true).is_ok());
        }
        assert_eq!(a.inflight(), 0);
        assert!(!a.pressured());
    }

    #[test]
    fn token_bucket_exhausts_and_prices_retry_after() {
        let a = adm(10.0, 3.0, 0);
        assert!(a.admit("t", false).is_ok());
        assert!(a.admit("t", false).is_ok());
        assert!(a.admit("t", false).is_ok());
        match a.admit("t", false) {
            Err(r @ Reject::RateLimited { retry_after_s }) => {
                assert!(retry_after_s > 0.0 && retry_after_s <= 0.2, "got {retry_after_s}");
                assert_eq!(r.reason(), "rate");
                assert!(r.retry_after() >= 1);
            }
            other => panic!("expected rate limit, got {other:?}"),
        }
        // A different tenant has its own bucket.
        assert!(a.admit("u", false).is_ok());
        let rej = a.rejected_snapshot();
        assert_eq!(rej, vec![("t".to_string(), "rate", 1)]);
    }

    #[test]
    fn gate_parks_sheds_and_fills() {
        let a = Arc::new(adm(0.0, 0.0, 1));
        let p1 = a.admit("t", false).unwrap();
        assert_eq!(a.inflight(), 1);
        assert!(a.pressured());
        // Saturated: expensive kinds shed immediately.
        assert_eq!(a.admit("t", true).unwrap_err(), Reject::Shed);
        // A cheap request parks; releasing the permit admits it.
        let a2 = Arc::clone(&a);
        let waiter = std::thread::spawn(move || a2.admit("t", false).map(|p| drop(p)).is_ok());
        // With one parked, the next cheap request overflows the queue.
        while a.gate.lock().unwrap().queued == 0 {
            std::thread::yield_now();
        }
        assert_eq!(a.admit("t", false).unwrap_err(), Reject::QueueFull);
        drop(p1);
        assert!(waiter.join().unwrap(), "parked waiter admitted after release");
        assert_eq!(a.inflight(), 0);
    }

    #[test]
    fn parked_waiter_detaches_on_deadline() {
        let a = adm(0.0, 0.0, 1);
        let _p = a.admit("t", false).unwrap();
        let _d = deadline::scope(Some(Instant::now() + Duration::from_millis(30)));
        let sw = Instant::now();
        assert_eq!(a.admit("t", false).unwrap_err(), Reject::DeadlineExceeded);
        assert!(sw.elapsed() < Duration::from_secs(5));
        assert_eq!(a.gate.lock().unwrap().queued, 0, "detached waiter left the queue");
    }

    #[test]
    fn shutdown_releases_parked_waiters() {
        let a = Arc::new(adm(0.0, 0.0, 1));
        let _p = a.admit("t", false).unwrap();
        let a2 = Arc::clone(&a);
        let waiter = std::thread::spawn(move || a2.admit("t", false).unwrap_err());
        while a.gate.lock().unwrap().queued == 0 {
            std::thread::yield_now();
        }
        a.shutdown();
        assert_eq!(waiter.join().unwrap(), Reject::ShuttingDown);
        // New arrivals are refused outright.
        assert_eq!(a.admit("t", false).unwrap_err(), Reject::ShuttingDown);
    }

    #[test]
    fn stats_json_carries_counters() {
        let a = adm(1000.0, 1.0, 0);
        assert!(a.admit("acme", false).is_ok());
        let _ = a.admit("acme", false); // bucket drained
        a.note_deadline_hit();
        let s = a.to_json().render();
        assert!(s.contains("\"acme:rate\":1"), "stats were {s}");
        assert!(s.contains("\"deadline_exceeded\":1"), "stats were {s}");
    }
}
