"""L2 + AOT pipeline tests: model graphs, HLO text emission, meta."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.ref import spmv_ell_ref


def small_case(n=512, k=4, m=512, seed=0):
    rng = np.random.default_rng(seed)
    cols = jnp.asarray(rng.integers(0, m, size=(n, k), dtype=np.int32))
    vals = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    return cols, vals, x


def test_model_spmv_variants_agree():
    cols, vals, x = small_case()
    (a,) = model.spmv_ell(cols, vals, x)
    (b,) = model.spmv_ell_pallas(cols, vals, x)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_model_pagerank_step_delta():
    y = jnp.asarray(np.full(8, 0.5, np.float32))
    old = jnp.zeros(8, jnp.float32)
    new, delta = model.pagerank_step(y, old, jnp.float32(0.85), jnp.float32(0.15 / 8))
    np.testing.assert_allclose(new, 0.15 / 8 + 0.85 * 0.5, rtol=1e-6)
    np.testing.assert_allclose(delta, float(np.sum(np.abs(np.asarray(new)))), rtol=1e-6)


def test_lower_all_emits_hlo_text():
    arts = aot.lower_all(n_tile=512, k=4)
    assert set(arts) == {"spmv_ell", "spmv_ell_pallas", "pagerank_step"}
    for name, text in arts.items():
        assert "HloModule" in text, name
        # The 0.5.1-compat path must not ship raw stablehlo.
        assert "stablehlo." not in text.splitlines()[0], name


def test_hlo_text_is_parameterized_correctly():
    arts = aot.lower_all(n_tile=512, k=4)
    spmv = arts["spmv_ell"]
    # 3 parameters: cols, vals, x with the right shapes.
    assert "s32[512,4]" in spmv
    assert "f32[512,4]" in spmv
    assert "f32[512]" in spmv


def test_main_writes_artifacts(tmp_path, monkeypatch):
    out = tmp_path / "arts"
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(out), "--n-tile", "512", "--k", "4"],
    )
    aot.main()
    files = sorted(os.listdir(out))
    assert "meta.json" in files
    assert "spmv_ell.hlo.txt" in files
    assert "spmv_ell_pallas.hlo.txt" in files
    assert "pagerank_step.hlo.txt" in files
    meta = json.loads((out / "meta.json").read_text())
    assert meta["n_tile"] == 512 and meta["k"] == 4
    assert meta["interchange"] == "hlo-text"


def test_compiled_artifact_executes_on_cpu_pjrt():
    """Round-trip: lowered HLO text → XlaComputation → compile → run.

    This is the same path the Rust runtime takes (via the xla crate), so
    numerics here certify what the coordinator will see.
    """
    from jax._src.lib import xla_client as xc

    arts = aot.lower_all(n_tile=512, k=4)
    # Parse back through the HLO text parser like the Rust side does.
    cols, vals, x = small_case(512, 4, 512, 3)
    want = spmv_ell_ref(cols, vals, x)

    got = jax.jit(model.spmv_ell)(cols, vals, x)[0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert len(arts["spmv_ell"]) > 100
