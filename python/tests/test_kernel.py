"""L1 correctness: Pallas SpMV-ELL kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled hot path — the
shape/seed sweep below is the offline stand-in for a hypothesis sweep
(deterministic seeds, dense coverage of tile-divisibility edge cases,
padding, duplicate columns, and adversarial value patterns).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.ref import spmv_ell_ref, pagerank_step_ref, degree_ref
from compile.kernels.spmv_ell import spmv_ell, vmem_footprint_bytes


def make_case(n, k, m, seed, pad_fraction=0.3):
    """Random ELL instance: cols/vals with ~pad_fraction zeroed slots."""
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, m, size=(n, k), dtype=np.int32)
    vals = rng.standard_normal((n, k), dtype=np.float32)
    pad = rng.random((n, k)) < pad_fraction
    vals[pad] = 0.0
    x = rng.standard_normal(m, dtype=np.float32)
    return jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)


SWEEP = [
    # (n, k, m, rows_tile)
    (512, 1, 512, 512),
    (512, 16, 512, 512),
    (1024, 16, 4096, 512),
    (1024, 3, 128, 256),
    (2048, 32, 2048, 512),
    (512, 16, 7, 512),      # tiny x: heavy duplicate gathers
    (4096, 8, 65536, 1024),  # x much larger than a row tile
    (256, 64, 256, 128),
    (128, 128, 64, 128),
]


@pytest.mark.parametrize("n,k,m,rows_tile", SWEEP)
@pytest.mark.parametrize("seed", [0, 1])
def test_kernel_matches_ref_sweep(n, k, m, rows_tile, seed):
    cols, vals, x = make_case(n, k, m, seed)
    got = spmv_ell(cols, vals, x, rows_tile=rows_tile)
    want = spmv_ell_ref(cols, vals, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kernel_all_padding_rows_zero():
    n, k, m = 512, 8, 100
    cols = jnp.zeros((n, k), jnp.int32)
    vals = jnp.zeros((n, k), jnp.float32)
    x = jnp.ones((m,), jnp.float32)
    y = spmv_ell(cols, vals, x)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(n, np.float32))


def test_kernel_duplicate_columns_accumulate():
    # A row listing the same column twice must count it twice.
    n, k, m = 512, 4, 16
    cols = np.zeros((n, k), np.int32)
    vals = np.zeros((n, k), np.float32)
    cols[0] = [3, 3, 5, 0]
    vals[0] = [1.0, 1.0, 2.0, 0.0]
    x = np.arange(m, dtype=np.float32)
    y = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    assert y[0] == pytest.approx(3 + 3 + 2 * 5)


def test_kernel_identity_rows():
    # Row i reads exactly x[i] with weight 1 -> y == x (n == m).
    n = k = None
    n, k, m = 1024, 4, 1024
    cols = np.zeros((n, k), np.int32)
    vals = np.zeros((n, k), np.float32)
    cols[:, 0] = np.arange(n)
    vals[:, 0] = 1.0
    x = np.random.default_rng(7).standard_normal(m).astype(np.float32)
    y = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    np.testing.assert_allclose(y, x, rtol=1e-6)


def test_kernel_rejects_untiled_n():
    cols, vals, x = make_case(100, 4, 100, 0)
    with pytest.raises(AssertionError):
        spmv_ell(cols, vals, x, rows_tile=64)


def test_kernel_extreme_values_no_nan():
    cols, vals, x = make_case(512, 8, 512, 3)
    vals = vals * 1e20
    y = spmv_ell(cols, vals, x)
    want = spmv_ell_ref(cols, vals, x)
    np.testing.assert_allclose(y, want, rtol=1e-4)


def test_pagerank_step_ref_shape():
    y = jnp.ones((16,), jnp.float32)
    out = pagerank_step_ref(y, 0.85, 0.15 / 16)
    assert out.shape == (16,)
    np.testing.assert_allclose(out, 0.15 / 16 + 0.85)


def test_degree_ref_counts_nonzero():
    vals = jnp.asarray([[0.0, 1.0, 2.0], [0.0, 0.0, 0.0]], jnp.float32)
    cols = jnp.zeros((2, 3), jnp.int32)
    d = degree_ref(cols, vals)
    assert list(np.asarray(d)) == [2, 0]


def test_vmem_footprint_estimate_within_budget():
    # DESIGN.md §8: the default tile must fit a 16 MiB VMEM comfortably.
    fp = vmem_footprint_bytes(512, 32, 8192)
    assert fp < 4 << 20, fp


def test_kernel_under_jit_composition():
    # The kernel must compose with surrounding jitted jnp code (this is
    # what the L2 graph does before AOT lowering).
    cols, vals, x = make_case(512, 8, 512, 11)

    @jax.jit
    def wrapped(c, v, xx):
        return 2.0 * spmv_ell(c, v, xx) + 1.0

    got = wrapped(cols, vals, x)
    want = 2.0 * spmv_ell_ref(cols, vals, x) + 1.0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
