"""L1 — the Pallas SpMV-ELL kernel (the paper's compute hot spot).

Hardware adaptation (DESIGN.md §3): the paper's CUDA concern is
*coalescing* the gather ``x[cols]`` — one warp reads one row's neighbor
values, and BOBA's reordering makes those reads land in few cache lines.
On TPU the analogous resource is VMEM block granularity: the kernel tiles
rows into ``(ROWS_TILE, k)`` VMEM blocks (cols + vals) while keeping the
dense vector ``x`` VMEM-resident, so one block fetch per neighborhood is
the TPU translation of "one cache line per neighborhood" — exactly the
NBR objective the paper optimizes.

The kernel MUST run with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
validated against ``ref.spmv_ell_ref`` by ``python/tests/test_kernel.py``;
TPU performance is *estimated* analytically in DESIGN.md §8 (interpret
mode's wallclock is CPU-numpy and meaningless as a TPU proxy).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-tile height. 512 rows × 32 slots × 4 B ≈ 64 KiB per operand
# block — comfortably inside a TPU core's ~16 MiB VMEM alongside x.
ROWS_TILE = 512


def _spmv_ell_kernel(cols_ref, vals_ref, x_ref, y_ref):
    """One row-tile: gather + rowwise reduce.

    cols_ref: int32[R, k] VMEM block of column ids.
    vals_ref: f32[R, k] matching weights (0 in padding).
    x_ref:    f32[m] the full dense vector (VMEM-resident).
    y_ref:    f32[R] output block.
    """
    cols = cols_ref[...]
    vals = vals_ref[...]
    x = x_ref[...]
    # The gather the whole paper is about. On TPU this lowers to a VMEM
    # dynamic-gather; its locality (VMEM bank conflicts / HBM refills for
    # bigger-than-VMEM x) is what BOBA's label clustering improves.
    gathered = jnp.take(x, cols, axis=None, mode="clip")
    y_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("rows_tile",))
def spmv_ell(cols, vals, x, rows_tile=ROWS_TILE):
    """Pallas ELL SpMV: y[i] = Σ_j vals[i,j] · x[cols[i,j]].

    Shapes: cols int32[n, k], vals f32[n, k], x f32[m] → f32[n].
    ``n`` must be a multiple of ``rows_tile`` (the AOT wrapper pads).
    """
    n, k = cols.shape
    assert vals.shape == (n, k), (vals.shape, (n, k))
    assert n % rows_tile == 0, f"n={n} not a multiple of rows_tile={rows_tile}"
    grid = (n // rows_tile,)
    return pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((rows_tile, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),  # x resident per step
        ],
        out_specs=pl.BlockSpec((rows_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(cols, vals, x)


def vmem_footprint_bytes(rows_tile, k, m):
    """Analytic VMEM footprint of one grid step (DESIGN.md §8).

    cols + vals blocks, the resident x, and the y block. Used by the
    docs/benches to report the TPU estimate; no runtime effect.
    """
    return rows_tile * k * 4 * 2 + m * 4 + rows_tile * 4
