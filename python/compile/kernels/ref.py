"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its oracle bit-for-bit structure-wise (allclose for
floats) across the pytest shape/dtype sweep in ``python/tests``.

The compute is the paper's SpMV hot spot over a padded **ELL** layout:
``cols[n, k]`` holds up to ``k`` neighbor/column IDs per row, ``vals`` the
matching weights (0.0 in padding slots, whose col id is 0 by convention —
the zero weight annihilates the bogus gather). The gather ``x[cols]`` is
the paper's cache-critical access (Algorithm 1 line 4).
"""

import jax.numpy as jnp


def spmv_ell_ref(cols, vals, x):
    """Reference ELL SpMV: y[i] = sum_j vals[i, j] * x[cols[i, j]].

    Args:
      cols: int32[n, k] column indices (padding slots must carry val 0).
      vals: f32[n, k] weights.
      x: f32[m] dense input vector (m = number of columns).

    Returns:
      f32[n] output vector.
    """
    return jnp.sum(vals * x[cols], axis=1)


def pagerank_step_ref(y, damping, base):
    """Reference PageRank update: rank' = base + damping * y.

    ``y`` is the pull-SpMV of the weighted graph against the current rank
    vector; ``base`` folds the teleport term and dangling mass (computed
    by the L3 coordinator, which owns graph-global scalars).
    """
    return base + damping * y


def degree_ref(cols, vals):
    """Reference row-degree: counts non-padding slots (val != 0)."""
    return jnp.sum((vals != 0.0).astype(jnp.int32), axis=1)
