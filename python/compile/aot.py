"""AOT pipeline: lower the L2/L1 compute graphs to HLO text artifacts.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts`` → ``python -m compile.aot --out-dir ../artifacts``.
Emits:
  spmv_ell.hlo.txt         — plain-jnp ELL SpMV          (N_TILE × K)
  spmv_ell_pallas.hlo.txt  — Pallas-kernel ELL SpMV      (N_TILE × K)
  pagerank_step.hlo.txt    — rank update + L1 delta      (N_TILE)
  meta.json                — tile geometry the Rust runtime reads
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Tile geometry baked into the artifacts (PJRT executables have static
# shapes). The Rust runtime pads/splits CSR matrices to these tiles.
N_TILE = 8192  # rows per tile (multiple of the kernel's ROWS_TILE=512)
K = 16         # ELL slots per pass


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(n_tile=N_TILE, k=K):
    """Lower every artifact; returns {name: hlo_text}."""
    cols = jax.ShapeDtypeStruct((n_tile, k), jnp.int32)
    vals = jax.ShapeDtypeStruct((n_tile, k), jnp.float32)
    x = jax.ShapeDtypeStruct((n_tile,), jnp.float32)
    vec = jax.ShapeDtypeStruct((n_tile,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    out = {}
    out["spmv_ell"] = to_hlo_text(jax.jit(model.spmv_ell).lower(cols, vals, x))
    out["spmv_ell_pallas"] = to_hlo_text(
        jax.jit(model.spmv_ell_pallas).lower(cols, vals, x)
    )
    out["pagerank_step"] = to_hlo_text(
        jax.jit(model.pagerank_step).lower(vec, vec, scalar, scalar)
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n-tile", type=int, default=N_TILE)
    ap.add_argument("--k", type=int, default=K)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    artifacts = lower_all(args.n_tile, args.k)
    for name, text in artifacts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "n_tile": args.n_tile,
        "k": args.k,
        "artifacts": sorted(artifacts),
        "interchange": "hlo-text",
        "jax": jax.__version__,
    }
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
