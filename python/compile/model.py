"""L2 — JAX compute graphs for the AOT artifacts.

Build-time only: these functions are lowered once by ``aot.py`` into HLO
text that the Rust runtime (``rust/src/runtime``) loads through PJRT.
Python never runs at request time.

Two SpMV variants are exported — the plain jnp formulation and the
Pallas-kernel formulation (L1) — lowered to *separate artifacts* so the
Rust side can A/B them (they must agree numerically; the runtime tests
assert it), plus the PageRank update step.
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels.spmv_ell import spmv_ell as spmv_ell_pallas_kernel


def spmv_ell(cols, vals, x):
    """Plain-jnp ELL SpMV (the L2 graph without the Pallas kernel)."""
    return (ref.spmv_ell_ref(cols, vals, x),)


def spmv_ell_pallas(cols, vals, x):
    """ELL SpMV through the L1 Pallas kernel (interpret-mode lowering)."""
    return (spmv_ell_pallas_kernel(cols, vals, x),)


def pagerank_step(y, rank_old, damping, base):
    """One PageRank update on a padded tile.

    rank' = base + damping · y ; also emits the L1 delta Σ|rank' - rank_old|
    so the Rust loop can test convergence without a second pass.
    """
    rank_new = base + damping * y
    delta = jnp.sum(jnp.abs(rank_new - rank_old))
    return (rank_new, delta)


def degree_count(cols, vals):
    """Row degrees of an ELL tile (non-padding slot count).

    Exported to let the runtime cross-check tile packing; also the
    paper's remark "its runtime is comparable to that of computing
    degrees" gets an artifact-level analogue.
    """
    del cols  # degree is defined by the padding convention on vals
    return (ref.degree_ref(jnp.zeros_like(vals, dtype=jnp.int32), vals),)
